//! Per-op energy and settling-time accounting — the physical quantities
//! behind the energy/latency surrogate heads.
//!
//! SEMULATOR's emulator predicts whatever the golden circuit produces; to
//! make it answer architecture-exploration questions (energy per MAC,
//! settling latency) those quantities have to exist on the golden path
//! first. This module provides them in three layers:
//!
//! * **Instantaneous physics** — [`dissipated_power`] evaluates the
//!   closed-form `Σ V²·G` dissipation of every passive device in a
//!   [`Circuit`] under a solved unknown vector, and [`source_power`] the
//!   power delivered by the sources; on any DC operating point the two
//!   balance to numerical precision (pinned by a proptest for both the
//!   dense and sparse MNA backends).
//! * **Transient accumulation** — [`PowerAccum`] rides the fixed-step
//!   transient loop ([`crate::spice::transient`] threads it through when
//!   [`TranOptions::power`](crate::spice::TranOptions) is set),
//!   integrating `Σ V²·G·Δt` with the same right-endpoint rule as the
//!   backward-Euler step itself and tracking the last step at which any
//!   node voltage still moved more than the tolerance band — the
//!   settling-time estimate. The result is a [`PowerReport`] per golden
//!   solve.
//! * **Closed-form fast path** — [`estimate_fast`] mirrors the golden
//!   accounting for the structured solver (`E ≈ Σ v_read²·g·t_sense`
//!   with gate-drive scaling, settling from the slowest bitline RC), so
//!   ideal/fast executors report energy without a netlist in sight.
//!
//! [`label_scales`] defines the normalization used when these quantities
//! become dataset label columns (datagen appends `[energy, t_settle]`
//! after the MAC outputs; the multi-head trainer regresses all three).
//! [`record_golden`] / [`record_fast`] quantize reports onto the global
//! obs counters (`golden_energy_fj`, `settling_ps`, `fast_energy_fj`) so
//! campaigns and `semulator stats` can aggregate them deterministically.

use crate::spice::devices::{mos_eval, switch_g};
use crate::spice::{Circuit, Device};
use crate::util::Json;
use crate::xbar::{BlockConfig, CellInputs};

/// Number of auxiliary label columns appended by power-aware datagen
/// (`energy`, `t_settle`), and of extra output heads on a power-extended
/// regression network.
pub const POWER_HEADS: usize = 2;

/// Knobs for transient power/settling accounting.
#[derive(Debug, Clone, Copy)]
pub struct PowerOptions {
    /// Settling tolerance band (V): the settling time is the last accepted
    /// timepoint at which any node voltage moved more than this within one
    /// step. At the fixed steps the crossbar blocks use, per-step movement
    /// is a faithful convergence proxy.
    pub settle_band: f64,
}

impl Default for PowerOptions {
    fn default() -> Self {
        Self { settle_band: 1e-4 }
    }
}

/// Energy and settling estimate of one solve (golden or fast path).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerReport {
    /// Energy dissipated in passive devices over the solve window (J).
    pub energy: f64,
    /// Settling-time estimate (s); `0.0` means settled from the start.
    pub t_settle: f64,
    /// Mean dissipated power over the window (W).
    pub p_avg: f64,
}

impl PowerReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("energy", Json::Num(self.energy)),
            ("t_settle", Json::Num(self.t_settle)),
            ("p_avg", Json::Num(self.p_avg)),
        ])
    }
}

/// Instantaneous power dissipated by every passive device under unknown
/// vector `x` at time `t` (W).
///
/// Resistors and switches contribute `V²·G`; diodes, RRAM cells and
/// MOSFETs contribute `I(V)·V` from the same device-model evaluations the
/// MNA stamps use. Capacitors store rather than dissipate, and sources /
/// controlled sources are active elements counted by [`source_power`].
pub fn dissipated_power(ckt: &Circuit, x: &[f64], t: f64) -> f64 {
    use crate::spice::node_v;
    let mut p_total = 0.0f64;
    for dev in &ckt.devices {
        match dev {
            Device::Resistor { p, n, r } => {
                let v = node_v(x, *p) - node_v(x, *n);
                p_total += v * v / r;
            }
            Device::Switch { p, n, g_on, g_off, on } => {
                let v = node_v(x, *p) - node_v(x, *n);
                p_total += v * v * switch_g(*g_on, *g_off, on, t);
            }
            Device::Diode { p, n, model } => {
                let v = node_v(x, *p) - node_v(x, *n);
                let (i, _) = model.eval(v);
                p_total += i * v;
            }
            Device::Rram { p, n, model } => {
                let v = node_v(x, *p) - node_v(x, *n);
                let (i, _) = model.eval(v);
                p_total += i * v;
            }
            Device::Mosfet { d, g, s, model } => {
                let vd = node_v(x, *d);
                let vg = node_v(x, *g);
                let vs = node_v(x, *s);
                let op = mos_eval(model, vd, vg, vs);
                p_total += op.id * (vd - vs);
            }
            Device::MosfetFg { d, s, vg, model } => {
                let vd = node_v(x, *d);
                let vs = node_v(x, *s);
                let op = mos_eval(model, vd, *vg, vs);
                p_total += op.id * (vd - vs);
            }
            // Storage and active elements: not dissipation.
            Device::Capacitor { .. }
            | Device::VSource { .. }
            | Device::ISource { .. }
            | Device::Vccs { .. } => {}
        }
    }
    p_total
}

/// Instantaneous power delivered by the circuit's sources under unknown
/// vector `x` at time `t` (W).
///
/// Voltage sources read their branch current out of the MNA unknown
/// vector (ordered after the node voltages, in device order); current and
/// controlled sources deliver `I·(v_n − v_p)` by the `p→n` through-device
/// sign convention. On a resistive DC operating point this equals
/// [`dissipated_power`] exactly (Tellegen's theorem).
pub fn source_power(ckt: &Circuit, x: &[f64], t: f64) -> f64 {
    use crate::spice::node_v;
    let branch_base = ckt.n_nodes() - 1;
    let mut branch = 0usize;
    let mut p_total = 0.0f64;
    for dev in &ckt.devices {
        match dev {
            Device::VSource { p, n, .. } => {
                let i = x[branch_base + branch];
                // Branch current is positive *into* the + terminal, so the
                // source delivers -v_pn * i (1 V across 1 kOhm solves to
                // i = -1 mA and delivers +1 mW).
                p_total -= (node_v(x, *p) - node_v(x, *n)) * i;
                branch += 1;
            }
            Device::ISource { p, n, wave } => {
                let i = wave.at(t);
                p_total += i * (node_v(x, *n) - node_v(x, *p));
            }
            Device::Vccs { p, n, cp, cn, gm } => {
                let i = gm * (node_v(x, *cp) - node_v(x, *cn));
                p_total += i * (node_v(x, *n) - node_v(x, *p));
            }
            _ => {}
        }
    }
    p_total
}

/// Static power report of a DC operating point held for `t_hold` seconds.
pub fn dc_power_report(ckt: &Circuit, x: &[f64], t_hold: f64) -> PowerReport {
    let p = dissipated_power(ckt, x, 0.0);
    PowerReport { energy: p * t_hold, t_settle: 0.0, p_avg: p }
}

/// Running energy/settling accumulator for the transient loop.
///
/// [`crate::spice::transient`] owns one of these when
/// `TranOptions::power` is set and calls [`Self::step`] once per accepted
/// timepoint with the committed unknown vector.
#[derive(Debug, Clone)]
pub struct PowerAccum {
    opts: PowerOptions,
    /// Node-voltage unknown count (settling watches only these, not the
    /// voltage-source branch currents).
    n_v: usize,
    energy: f64,
    t_settle: f64,
    prev_v: Vec<f64>,
    primed: bool,
}

impl PowerAccum {
    pub fn new(ckt: &Circuit, opts: PowerOptions) -> Self {
        let n_v = ckt.n_nodes() - 1;
        Self { opts, n_v, energy: 0.0, t_settle: 0.0, prev_v: vec![0.0; n_v], primed: false }
    }

    /// Record the initial point (t = 0) without integrating energy.
    pub fn prime(&mut self, x: &[f64]) {
        self.prev_v.copy_from_slice(&x[..self.n_v]);
        self.primed = true;
    }

    /// Account one accepted step of width `h` ending at time `t` with
    /// committed unknown vector `x`.
    pub fn step(&mut self, ckt: &Circuit, h: f64, t: f64, x: &[f64]) {
        // Right-endpoint rule, consistent with the backward-Euler step
        // that produced `x`.
        self.energy += dissipated_power(ckt, x, t) * h;
        let mut max_dv = 0.0f64;
        for (i, prev) in self.prev_v.iter_mut().enumerate() {
            max_dv = max_dv.max((x[i] - *prev).abs());
            *prev = x[i];
        }
        // An unprimed accumulator treats the first step's full swing as
        // movement, which is the conservative choice.
        if !self.primed || max_dv > self.opts.settle_band {
            self.t_settle = t;
        }
        self.primed = true;
    }

    pub fn finish(self, t_total: f64) -> PowerReport {
        let p_avg = if t_total > 0.0 { self.energy / t_total } else { 0.0 };
        PowerReport { energy: self.energy, t_settle: self.t_settle, p_avg }
    }
}

/// Closed-form fast-path estimate matching the golden accounting in
/// spirit: per-cell read power `v_read²·g` scaled by the normalized gate
/// drive (a cut-off access transistor passes no current), integrated over
/// the sense window; settling from the slowest bitline RC (3τ, capped at
/// the window) against the output stage RC.
///
/// Callers are expected to pass *non-ideality-perturbed* cell inputs
/// (`FastSolver::estimate_power` applies the frozen transform first) so
/// fast and golden energy labels see the same device corner.
pub fn estimate_fast(cfg: &BlockConfig, x: &CellInputs) -> PowerReport {
    let n = cfg.n_cells();
    assert_eq!(x.v.len(), n, "cell input length");
    assert_eq!(x.g.len(), n, "cell conductance length");
    let v2 = cfg.v_read * cfg.v_read;
    let mut p_total = 0.0f64;
    let mut g_col = vec![0.0f64; cfg.cols];
    for k in 0..n {
        let drive = (x.v[k] / cfg.v_gate_max).clamp(0.0, 1.0);
        let g_eff = x.g[k] * drive;
        p_total += v2 * g_eff;
        g_col[k % cfg.cols] += g_eff;
    }
    let energy = p_total * cfg.t_sense;
    // Slowest column: sense cap against the column's total conductance.
    let mut tau_max = cfg.periph.r_load * cfg.periph.c_load;
    for &g in &g_col {
        let tau = if g > 0.0 { cfg.periph.c_sense / g } else { f64::INFINITY };
        tau_max = tau_max.max(tau);
    }
    let t_settle = (3.0 * tau_max).min(cfg.t_sense);
    PowerReport { energy, t_settle, p_avg: p_total }
}

/// Label normalization scales `(e_scale, t_scale)` for power-aware
/// datasets: energy columns are stored as `energy / e_scale`, settling
/// columns as `t_settle / t_scale`, keeping the auxiliary heads in the
/// same O(1) range as the MAC voltage targets. The scales are pure
/// functions of the block config, so labels stay worker-invariant and
/// physical units recover exactly from the meta sidecar.
pub fn label_scales(cfg: &BlockConfig) -> (f64, f64) {
    let e_scale =
        (cfg.v_read * cfg.v_read * cfg.cell.g_max * cfg.n_cells() as f64 * cfg.t_sense).max(1e-30);
    (e_scale, cfg.t_sense)
}

/// Quantize a golden-path report onto the global obs counters
/// (femtojoules / picoseconds — integer, deterministic, summable).
pub fn record_golden(r: &PowerReport) {
    crate::obs::counters::add_golden_energy_fj((r.energy * 1e15).round().max(0.0) as u64);
    crate::obs::counters::add_settling_ps((r.t_settle * 1e12).round().max(0.0) as u64);
}

/// Quantize a fast-path estimate onto the global obs counters.
pub fn record_fast(r: &PowerReport) {
    crate::obs::counters::add_fast_energy_fj((r.energy * 1e15).round().max(0.0) as u64);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spice::{dc_op, transient, NrOptions, TranOptions, Waveform, GND};

    #[test]
    fn dc_divider_balances_exactly() {
        let mut c = Circuit::new();
        let a = c.node("a");
        let b = c.node("b");
        c.vdc(a, GND, 2.0).resistor(a, b, 1e3).resistor(b, GND, 1e3);
        let x = dc_op(&c, &NrOptions::default()).unwrap();
        let diss = dissipated_power(&c, &x, 0.0);
        let src = source_power(&c, &x, 0.0);
        // 2 V across 2 kOhm total: 2 mW.
        assert!((diss - 2e-3).abs() < 1e-12, "diss {diss}");
        assert!((src - diss).abs() < 1e-12, "source {src} vs dissipated {diss}");
        let rep = dc_power_report(&c, &x, 1e-6);
        assert!((rep.energy - 2e-9).abs() < 1e-18);
        assert_eq!(rep.t_settle, 0.0);
    }

    #[test]
    fn isource_and_vccs_deliver() {
        let mut c = Circuit::new();
        let a = c.node("a");
        c.isource(GND, a, Waveform::Dc(1e-3)).resistor(a, GND, 1e3);
        let x = dc_op(&c, &NrOptions::default()).unwrap();
        assert!((source_power(&c, &x, 0.0) - 1e-3).abs() < 1e-12);
        assert!((dissipated_power(&c, &x, 0.0) - 1e-3).abs() < 1e-12);

        let mut c = Circuit::new();
        let vin = c.node("in");
        let out = c.node("out");
        c.vdc(vin, GND, 0.5);
        c.vccs(out, GND, vin, GND, 1e-3).resistor(out, GND, 1e3);
        let x = dc_op(&c, &NrOptions::default()).unwrap();
        // The VCCS drives -0.5 V into 1k (0.25 mW); the input vsource
        // sources no current, so total delivery equals the dissipation.
        let diss = dissipated_power(&c, &x, 0.0);
        let src = source_power(&c, &x, 0.0);
        assert!((diss - 0.25e-3).abs() < 1e-12, "diss {diss}");
        assert!((src - diss).abs() < 1e-12, "src {src}");
    }

    #[test]
    fn nonlinear_dc_balance_within_gmin_slop() {
        use crate::spice::DiodeModel;
        let mut c = Circuit::new();
        let a = c.node("a");
        let k = c.node("k");
        c.vdc(a, GND, 5.0).resistor(a, k, 1e3).diode(k, GND, DiodeModel::default());
        let x = dc_op(&c, &NrOptions::default()).unwrap();
        let diss = dissipated_power(&c, &x, 0.0);
        let src = source_power(&c, &x, 0.0);
        // gmin leaks carry ~1e-12 S worth of current; the balance holds to
        // well under a ppm of the ~20 mW flowing.
        assert!((src - diss).abs() < 1e-9 * src.abs().max(1.0), "{src} vs {diss}");
    }

    #[test]
    fn transient_rc_energy_and_settling() {
        // RC charge-up: after >> 5 tau, the resistor has dissipated
        // C V^2 / 2 (equal to the energy stored on the cap) and every node
        // has stopped moving well before t_stop.
        let mut c = Circuit::new();
        let a = c.node("a");
        let b = c.node("b");
        c.vdc(a, GND, 1.0).resistor(a, b, 1e3).capacitor(b, GND, 1e-9); // tau = 1 us
        let mut opts = TranOptions::new(20e-6, 2e-8);
        opts.uic = true;
        opts.power = Some(PowerOptions::default());
        let res = transient(&c, &opts, &NrOptions::default()).unwrap();
        let rep = res.power.expect("power accounting requested");
        let expect = 0.5 * 1e-9 * 1.0; // C V^2 / 2
        assert!(
            (rep.energy - expect).abs() < 0.05 * expect,
            "energy {} vs CV^2/2 {expect}",
            rep.energy
        );
        assert!(rep.t_settle > 0.0 && rep.t_settle < 15e-6, "t_settle {}", rep.t_settle);
        assert!(rep.p_avg > 0.0);
        // Without the option the report is absent and results identical.
        let mut plain = TranOptions::new(20e-6, 2e-8);
        plain.uic = true;
        let res2 = transient(&c, &plain, &NrOptions::default()).unwrap();
        assert!(res2.power.is_none());
        assert_eq!(res.x_final, res2.x_final, "accounting perturbed the solve");
    }

    #[test]
    fn fast_estimate_scales_with_drive_and_conductance() {
        let cfg = BlockConfig::small();
        let zero = CellInputs::zeros(&cfg);
        let quiet = estimate_fast(&cfg, &zero);
        assert_eq!(quiet.energy, 0.0, "no gate drive, no read current");
        let mut on = CellInputs::zeros(&cfg);
        for k in 0..cfg.n_cells() {
            on.v[k] = cfg.v_gate_max;
            on.g[k] = cfg.cell.g_max;
        }
        let loud = estimate_fast(&cfg, &on);
        let expect = cfg.v_read * cfg.v_read
            * cfg.cell.g_max
            * cfg.n_cells() as f64
            * cfg.t_sense;
        assert!((loud.energy - expect).abs() < 1e-12 * expect.max(1.0), "{}", loud.energy);
        assert!(loud.t_settle > 0.0 && loud.t_settle <= cfg.t_sense);
        assert!(loud.t_settle <= quiet.t_settle.max(cfg.t_sense));
        // Energy normalizes to <= 1 under the label scale by construction.
        let (e_scale, t_scale) = label_scales(&cfg);
        assert!(loud.energy / e_scale <= 1.0 + 1e-12);
        assert!(loud.t_settle / t_scale <= 1.0 + 1e-12);
    }

    #[test]
    fn report_json_has_stable_keys() {
        let rep = PowerReport { energy: 1.5e-12, t_settle: 4.2e-8, p_avg: 7.5e-6 };
        let j = crate::util::json_parse(&rep.to_json().to_string_pretty()).unwrap();
        assert_eq!(j.get("energy").unwrap().as_f64(), Some(1.5e-12));
        assert_eq!(j.get("t_settle").unwrap().as_f64(), Some(4.2e-8));
        assert_eq!(j.get("p_avg").unwrap().as_f64(), Some(7.5e-6));
    }
}
