//! The native inference engine: SEMULATOR forward passes straight from a
//! [`ModelState`], no PJRT, no artifacts.
//!
//! Build-time packing turns every layer into one [`matmul_nt`] call:
//!
//! * conv weights `(Cout, Cin, kD, kH, kW)` are row-major, so they already
//!   are the packed `(Cout, K = Cin*kD*kH*kW)` left operand; a precomputed
//!   gather table turns each sample into the `(P, K)` patch matrix
//!   (im2col), and the product lands channel-major `(Cout, P)` — exactly
//!   the next layer's `(C, D', H', W')` row-major input, so flatten is
//!   free.
//! * dense weights `(K, N)` are pre-transposed once to `(N, K)`.
//!
//! Bias + CELU run as a fused single-pass epilogue. Execution is
//! *layer-major*: each layer runs over the whole batch before the next
//! starts, so a dense layer is exactly one [`matmul_nt_with`] call (which
//! threads itself over output rows and dispatches SIMD internally) and a
//! conv layer fans sample blocks of its output buffer over
//! [`crate::util::parallel_chunks_mut`] with one per-sample GEMM each.
//! One kernel call per logical matmul also keeps the `kernel_flops` /
//! `kernel_bytes` obs counters byte-identical across worker counts —
//! the chunked layout used to recount the weight operand once per batch
//! chunk.

use anyhow::{Context, Result};

use crate::model::ModelState;
use crate::runtime::VariantMeta;
use crate::util::{default_workers, parallel_chunks_mut};

use super::arch::{Arch, Layer};
use super::kernels::{bias_celu_cols, bias_celu_rows, matmul_nt_with};
use super::{BackendKind, EmulatorBackend, VariantId, VariantShape};

/// Below this many samples per worker, extra threads cost more than they
/// save (the small variant's forward is ~µs per sample).
const MIN_CHUNK: usize = 16;

enum Packed {
    Conv {
        cout: usize,
        /// Patch width `Cin * kD * kH * kW`.
        k: usize,
        /// Output spatial positions `D' * H' * W'`.
        p: usize,
        /// `p * k` input indices: `gather[pp * k + q]` is the sample-local
        /// source of patch row `pp`, column `q`.
        gather: Vec<u32>,
        w: Vec<f32>,
        b: Vec<f32>,
        celu: bool,
        in_len: usize,
        out_len: usize,
    },
    Dense {
        k: usize,
        n: usize,
        /// `(n, k)` pre-transposed weight.
        wt: Vec<f32>,
        b: Vec<f32>,
        celu: bool,
    },
}

/// Pure-Rust [`EmulatorBackend`]: packed weights + gather tables.
///
/// One engine executes one `(architecture, checkpoint)` pair; as a backend
/// it therefore serves exactly one variant (id 0). Deployments hosting
/// several named variants stack engines in a
/// [`NativeRegistry`](super::NativeRegistry).
pub struct NativeEngine {
    /// Single-entry shape table: the one source of the engine's
    /// name/geometry (the v2 backend contract is slice-based).
    shape: [VariantShape; 1],
    layers: Vec<Packed>,
    workers: usize,
}

impl NativeEngine {
    /// Pack `state` for `arch`. Validates that the parameter layout matches
    /// the architecture before touching any data.
    pub fn new(arch: &Arch, state: &ModelState) -> Result<Self> {
        arch.validate().with_context(|| format!("arch '{}'", arch.name))?;
        let specs = arch.param_specs();
        anyhow::ensure!(
            specs.len() == state.arrays.len(),
            "state has {} parameter arrays, arch '{}' wants {}",
            state.arrays.len(),
            arch.name,
            specs.len()
        );
        for ((spec, sspec), arr) in specs.iter().zip(&state.specs).zip(&state.arrays) {
            anyhow::ensure!(
                spec.shape == sspec.shape && spec.numel() == arr.len(),
                "array '{}': state shape {:?} != arch shape {:?}",
                sspec.name,
                sspec.shape,
                spec.shape
            );
        }

        let mut layers = Vec::new();
        let mut c = arch.input[0];
        let mut dims = [arch.input[1], arch.input[2], arch.input[3]];
        let mut pi = 0usize;
        for ly in &arch.layers {
            match ly {
                Layer::Conv { cin, cout, k, s, celu } => {
                    let (w, b) = (&state.arrays[pi], &state.arrays[pi + 1]);
                    pi += 2;
                    let [d_in, h_in, w_in] = dims;
                    let od = (d_in - k[0]) / s[0] + 1;
                    let oh = (h_in - k[1]) / s[1] + 1;
                    let ow = (w_in - k[2]) / s[2] + 1;
                    let kq = cin * k[0] * k[1] * k[2];
                    let p = od * oh * ow;
                    let in_len = c * d_in * h_in * w_in;
                    let mut gather = Vec::with_capacity(p * kq);
                    for zd in 0..od {
                        for zh in 0..oh {
                            for zw in 0..ow {
                                for ci in 0..*cin {
                                    for kd in 0..k[0] {
                                        for kh in 0..k[1] {
                                            for kw in 0..k[2] {
                                                let xi = ((ci * d_in + zd * s[0] + kd) * h_in
                                                    + zh * s[1]
                                                    + kh)
                                                    * w_in
                                                    + zw * s[2]
                                                    + kw;
                                                gather.push(xi as u32);
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                    layers.push(Packed::Conv {
                        cout: *cout,
                        k: kq,
                        p,
                        gather,
                        w: w.clone(),
                        b: b.clone(),
                        celu: *celu,
                        in_len,
                        out_len: cout * p,
                    });
                    c = *cout;
                    dims = [od, oh, ow];
                }
                Layer::Flatten => {
                    // Channel-major conv output row-major == flat layout.
                    c *= dims[0] * dims[1] * dims[2];
                    dims = [1, 1, 1];
                }
                Layer::Dense { cin, cout, celu } => {
                    let (w, b) = (&state.arrays[pi], &state.arrays[pi + 1]);
                    pi += 2;
                    layers.push(Packed::Dense {
                        k: *cin,
                        n: *cout,
                        wt: super::kernels::transpose_pack(w, *cin, *cout),
                        b: b.clone(),
                        celu: *celu,
                    });
                    c = *cout;
                }
            }
        }
        Ok(Self {
            shape: [VariantShape {
                name: arch.name.clone(),
                n_features: arch.n_features(),
                n_outputs: arch.outputs,
            }],
            layers,
            workers: default_workers(),
        })
    }

    /// Build from a [`VariantMeta`] (reconstructing the architecture from
    /// the parameter layout — see [`Arch::from_meta`]).
    pub fn from_meta(meta: &VariantMeta, state: &ModelState) -> Result<Self> {
        Self::new(&Arch::from_meta(meta)?, state)
    }

    /// Override the worker-thread count (default: all cores).
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    pub fn variant(&self) -> &str {
        &self.shape[0].name
    }

    /// Normalized features per sample.
    pub fn n_features(&self) -> usize {
        self.shape[0].n_features
    }

    /// Outputs (MAC voltages) per sample.
    pub fn n_outputs(&self) -> usize {
        self.shape[0].n_outputs
    }

    /// Forward a batch laid out `batch * n_features` batch-major; returns
    /// `batch * n_outputs`. Runs layer-major: every layer processes the
    /// whole batch (threading inside the layer) before the next starts.
    pub fn forward(&self, x: &[f32]) -> Result<Vec<f32>> {
        let n_features = self.n_features();
        anyhow::ensure!(
            !x.is_empty() && x.len() % n_features == 0,
            "input length {} is not a nonzero multiple of {} features",
            x.len(),
            n_features
        );
        let batch = x.len() / n_features;
        let mut cur = x.to_vec();
        for ly in &self.layers {
            cur = self.forward_layer(ly, &cur, batch);
        }
        Ok(cur)
    }

    /// One layer over the whole batch.
    ///
    /// Dense: a single batch-wide GEMM — `matmul_nt_with` fans output
    /// rows over worker threads itself when the shape warrants it. Conv:
    /// [`MIN_CHUNK`]-sample blocks of the output buffer fan out over
    /// scoped threads, each running the per-sample gather + GEMM +
    /// epilogue serially (`max_workers = 1` — the batch loop is already
    /// parallel). Either way each logical matmul is counted exactly once,
    /// so the obs work counters do not depend on `self.workers`.
    fn forward_layer(&self, ly: &Packed, cur: &[f32], batch: usize) -> Vec<f32> {
        match ly {
            Packed::Conv { cout, k, p, gather, w, b, celu, in_len, out_len } => {
                let mut next = vec![0.0f32; batch * out_len];
                let tasks = self.workers.min(batch.div_ceil(MIN_CHUNK)).max(1);
                parallel_chunks_mut(&mut next, MIN_CHUNK * out_len, tasks, |ci, chunk| {
                    let mut patch = vec![0.0f32; p * k];
                    let base = ci * MIN_CHUNK;
                    for (s, out) in chunk.chunks_mut(*out_len).enumerate() {
                        let sample = &cur[(base + s) * in_len..(base + s + 1) * in_len];
                        for (dst, &src) in patch.iter_mut().zip(gather.iter()) {
                            *dst = sample[src as usize];
                        }
                        matmul_nt_with(w, &patch, *cout, *p, *k, out, 1);
                        bias_celu_rows(out, *cout, *p, b, *celu);
                    }
                });
                next
            }
            Packed::Dense { k, n, wt, b, celu } => {
                let mut next = vec![0.0f32; batch * n];
                matmul_nt_with(cur, wt, batch, *n, *k, &mut next, self.workers);
                bias_celu_cols(&mut next, batch, *n, b, *celu);
                next
            }
        }
    }
}

impl EmulatorBackend for NativeEngine {
    fn kind(&self) -> BackendKind {
        BackendKind::Native
    }

    fn variants(&self) -> &[VariantShape] {
        &self.shape
    }

    fn forward_batch(&self, variant: VariantId, inputs: &[f32]) -> Result<Vec<f32>> {
        anyhow::ensure!(
            variant == 0,
            "NativeEngine serves a single variant (id 0), got {variant}"
        );
        self.forward(inputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::reference;
    use crate::util::Rng;

    fn random_inputs(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from(seed);
        (0..n).map(|_| rng.range(-0.2, 1.2) as f32).collect()
    }

    #[test]
    fn matches_reference_on_all_builtin_variants() {
        for (vi, name) in ["small", "cfg_a", "cfg_b"].into_iter().enumerate() {
            let arch = Arch::for_variant(name).unwrap();
            let state = ModelState::init(&arch.to_meta(), 11 + vi as u64);
            let engine = NativeEngine::new(&arch, &state).unwrap();
            let x = random_inputs(3 * arch.n_features(), 50 + vi as u64);
            let got = engine.forward(&x).unwrap();
            let want = reference::forward(&arch, &state, &x).unwrap();
            assert_eq!(got.len(), want.len());
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() <= 1e-5, "{name}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn batch_is_row_independent() {
        let arch = Arch::for_variant("small").unwrap();
        let state = ModelState::init(&arch.to_meta(), 3);
        let engine = NativeEngine::new(&arch, &state).unwrap();
        let nf = arch.n_features();
        let x = random_inputs(5 * nf, 9);
        let batched = engine.forward(&x).unwrap();
        for row in 0..5 {
            let one = engine.forward(&x[row * nf..(row + 1) * nf]).unwrap();
            for (a, b) in one.iter().zip(&batched[row * arch.outputs..(row + 1) * arch.outputs]) {
                assert!((a - b).abs() <= 1e-6, "row {row}");
            }
        }
    }

    #[test]
    fn worker_count_does_not_change_results() {
        let arch = Arch::for_variant("small").unwrap();
        let state = ModelState::init(&arch.to_meta(), 5);
        let nf = arch.n_features();
        let x = random_inputs(64 * nf, 21);
        let serial = NativeEngine::new(&arch, &state).unwrap().with_workers(1);
        let parallel = NativeEngine::new(&arch, &state).unwrap().with_workers(4);
        assert_eq!(serial.forward(&x).unwrap(), parallel.forward(&x).unwrap());
    }

    #[test]
    fn forced_scalar_matches_reference_bit_exactly() {
        // The scalar kernels keep the naive per-output summation order,
        // so with SIMD forced off the packed engine reproduces the
        // reference oracle exactly — the regression anchor the SIMD
        // relative-tolerance tests hang off.
        let _g = crate::infer::kernels::force_scalar();
        let arch = Arch::for_variant("small").unwrap();
        let state = ModelState::init(&arch.to_meta(), 17);
        let engine = NativeEngine::new(&arch, &state).unwrap().with_workers(3);
        let x = random_inputs(7 * arch.n_features(), 71);
        let got = engine.forward(&x).unwrap();
        let want = crate::infer::reference::forward(&arch, &state, &x).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn work_counters_do_not_depend_on_worker_count() {
        // One kernel call per logical matmul: flops, bytes, and the SIMD
        // dispatch count must be byte-identical at 1 vs 4 workers (the
        // chunk-major layout used to recount weight bytes per chunk).
        use crate::obs::counters;
        let arch = Arch::for_variant("small").unwrap();
        let state = ModelState::init(&arch.to_meta(), 8);
        let x = random_inputs(64 * arch.n_features(), 31);
        let count = |workers: usize| {
            let set = std::sync::Arc::new(crate::obs::CounterSet::new());
            let _g = counters::scoped(set.clone());
            NativeEngine::new(&arch, &state).unwrap().with_workers(workers).forward(&x).unwrap();
            let s = set.snapshot();
            (s.kernel_flops, s.kernel_bytes, s.kernel_simd)
        };
        let serial = count(1);
        assert!(serial.0 > 0 && serial.1 > 0, "engine forward must count work: {serial:?}");
        assert_eq!(serial, count(4), "kernel counters must be worker-invariant");
    }

    #[test]
    fn rejects_mismatched_state() {
        let arch = Arch::for_variant("small").unwrap();
        let other = ModelState::init(&Arch::for_variant("cfg_a").unwrap().to_meta(), 0);
        assert!(NativeEngine::new(&arch, &other).is_err());
        let engine = NativeEngine::new(&arch, &ModelState::init(&arch.to_meta(), 0)).unwrap();
        assert!(engine.forward(&[0.0; 7]).is_err());
        assert!(engine.forward(&[]).is_err());
    }

    #[test]
    fn backend_trait_surface() {
        let arch = Arch::for_variant("small").unwrap();
        let state = ModelState::init(&arch.to_meta(), 1);
        let engine: Box<dyn EmulatorBackend> = Box::new(NativeEngine::new(&arch, &state).unwrap());
        assert_eq!(engine.kind(), BackendKind::Native);
        let shapes = engine.variants();
        assert_eq!(shapes.len(), 1);
        assert_eq!(shapes[0].name, "small");
        assert_eq!(shapes[0].n_features, 128); // (2, 2, 16, 2)
        assert_eq!(shapes[0].n_outputs, 1);
        assert_eq!(engine.variant_id("small").unwrap(), 0);
        assert!(engine.variant_id("nope").is_err());
        assert_eq!(engine.shape(0).unwrap().n_outputs, 1);
        assert!(engine.shape(1).is_err());
        assert_eq!(engine.max_batch(), None);
        let y = engine.forward_batch(0, &vec![0.4f32; 2 * 128]).unwrap();
        assert_eq!(y.len(), 2);
        assert!(engine.forward_batch(1, &vec![0.4f32; 128]).is_err());
    }
}
