//! Rust mirror of the SEMULATOR network architectures (paper Table 2).
//!
//! `python/compile/arch.py` remains the source of truth for the *artifact*
//! path; this module re-declares the same layer stacks so the native
//! inference engine can run without any Python-produced metadata, and can
//! also *reconstruct* an [`Arch`] from an `artifacts/meta.json`
//! ([`Arch::from_meta`]) so checkpoints trained against real artifacts are
//! served natively. Conv layers use VALID padding; the Conv4Xbar trunk
//! reads disjoint patches (stride == kernel), and the final conv's stride
//! is the one degree of freedom recovered from the first dense layer's
//! fan-in (see the cfg_b note in arch.py).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::{Meta, ParamSpec, VariantMeta};

/// The variants with a built-in architecture (usable with no artifacts).
pub const BUILTIN_VARIANTS: &[&str] = &["small", "cfg_a", "cfg_b"];

/// One layer of the regression network.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Layer {
    /// 3-D convolution over `(C, D, H, W)`, VALID padding, optional CELU.
    Conv { cin: usize, cout: usize, k: [usize; 3], s: [usize; 3], celu: bool },
    /// Reshape `(C, D, H, W)` row-major into a flat feature vector.
    Flatten,
    /// Fully connected `cin -> cout`, optional CELU.
    Dense { cin: usize, cout: usize, celu: bool },
}

/// A full network architecture: input tensor shape, output count, layers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Arch {
    pub name: String,
    /// Input tensor shape `(C, D, H, W)`, no batch dim.
    pub input: [usize; 4],
    pub outputs: usize,
    pub layers: Vec<Layer>,
}

fn conv(cin: usize, cout: usize, k: [usize; 3], s: [usize; 3]) -> Layer {
    Layer::Conv { cin, cout, k, s, celu: true }
}

fn dense(cin: usize, cout: usize, celu: bool) -> Layer {
    Layer::Dense { cin, cout, celu }
}

/// The shared Conv4Xbar trunk of Table 2: per-cell 1x1x1 features, then
/// column-wise (H) reductions, then the cross-column (W) mix.
fn xbar_stack(head_h: &[(usize, usize)], last_w_kernel: usize, last_w_stride: usize) -> Vec<Layer> {
    let mut layers = vec![conv(2, 16, [1, 1, 1], [1, 1, 1])];
    let mut cin = 16;
    for &(cout, kh) in head_h {
        layers.push(conv(cin, cout, [1, kh, 1], [1, kh, 1]));
        cin = cout;
    }
    layers.push(conv(cin, 32, [1, 1, last_w_kernel], [1, 1, last_w_stride]));
    layers
}

impl Arch {
    /// The built-in architecture for a known variant (`small`, `cfg_a`,
    /// `cfg_b`) — mirrors `python/compile/arch.py` exactly, including the
    /// cfg_b last-conv stride (1,1,2) that makes its Linear(256, 32)
    /// type-check.
    pub fn for_variant(name: &str) -> Result<Arch> {
        let arch = match name {
            "cfg_a" => {
                let mut layers = xbar_stack(&[(8, 2), (4, 4), (32, 8)], 2, 1);
                layers.push(Layer::Flatten);
                layers.push(dense(128, 32, true));
                layers.push(dense(32, 16, true));
                layers.push(dense(16, 1, false));
                Arch { name: name.into(), input: [2, 4, 64, 2], outputs: 1, layers }
            }
            "cfg_b" => {
                let mut layers = xbar_stack(&[(8, 2), (4, 4), (32, 8)], 2, 2);
                layers.push(Layer::Flatten);
                layers.push(dense(256, 32, true));
                layers.push(dense(32, 16, true));
                layers.push(dense(16, 4, false));
                Arch { name: name.into(), input: [2, 2, 64, 8], outputs: 4, layers }
            }
            "small" => {
                let mut layers = xbar_stack(&[(8, 2), (32, 8)], 2, 1);
                layers.push(Layer::Flatten);
                layers.push(dense(64, 32, true));
                layers.push(dense(32, 16, true));
                layers.push(dense(16, 1, false));
                Arch { name: name.into(), input: [2, 2, 16, 2], outputs: 1, layers }
            }
            other => bail!(
                "no built-in architecture for variant '{other}' (have: {})",
                BUILTIN_VARIANTS.join(" | ")
            ),
        };
        arch.validate().with_context(|| format!("built-in arch '{name}'"))?;
        Ok(arch)
    }

    /// Features per sample (product of input dims).
    pub fn n_features(&self) -> usize {
        self.input.iter().product()
    }

    /// The same network with `extra` additional output heads: the final
    /// dense layer widens by `extra` and `outputs` grows to match, under
    /// the same variant name. This is how a power-enabled run trains the
    /// `[mac, energy, t_settle]` multi-output emulator (see
    /// [`crate::power`]) without declaring a new variant.
    pub fn with_extra_outputs(&self, extra: usize) -> Result<Arch> {
        let mut arch = self.clone();
        match arch.layers.last_mut() {
            Some(Layer::Dense { cout, .. }) => *cout += extra,
            other => bail!(
                "arch '{}': cannot widen outputs — last layer is {:?}, not dense",
                self.name,
                other
            ),
        }
        arch.outputs += extra;
        arch.validate().with_context(|| format!("arch '{}' + {extra} heads", self.name))?;
        Ok(arch)
    }

    /// Shape-check the layer stack; returns the flattened feature count.
    pub fn validate(&self) -> Result<usize> {
        let mut c = self.input[0];
        let mut spatial = [self.input[1], self.input[2], self.input[3]];
        let mut flat = 0usize;
        let mut seen_flatten = false;
        for (i, ly) in self.layers.iter().enumerate() {
            match ly {
                Layer::Conv { cin, cout, k, s, .. } => {
                    anyhow::ensure!(!seen_flatten, "layer {i}: conv after flatten");
                    anyhow::ensure!(*cin == c, "layer {i}: conv cin {cin} != incoming {c}");
                    spatial = conv_out_shape(spatial, *k, *s)
                        .with_context(|| format!("layer {i}: conv {k:?}/{s:?} on {spatial:?}"))?;
                    c = *cout;
                }
                Layer::Flatten => {
                    anyhow::ensure!(!seen_flatten, "layer {i}: repeated flatten");
                    seen_flatten = true;
                    flat = c * spatial[0] * spatial[1] * spatial[2];
                    c = flat;
                }
                Layer::Dense { cin, cout, .. } => {
                    anyhow::ensure!(seen_flatten, "layer {i}: dense before flatten");
                    anyhow::ensure!(*cin == c, "layer {i}: dense cin {cin} != incoming {c}");
                    c = *cout;
                }
            }
        }
        anyhow::ensure!(c == self.outputs, "final width {c} != outputs {}", self.outputs);
        Ok(flat)
    }

    /// Ordered parameter descriptors (name, shape, Kaiming-uniform bound) —
    /// identical naming/ordering to `python/compile/arch.py::param_specs`
    /// (indices enumerate *layers*, so flatten skips an index).
    pub fn param_specs(&self) -> Vec<ParamSpec> {
        let mut specs = Vec::new();
        for (i, ly) in self.layers.iter().enumerate() {
            match ly {
                Layer::Conv { cin, cout, k, .. } => {
                    let fan_in = cin * k[0] * k[1] * k[2];
                    let bound = (1.0 / fan_in as f64).sqrt();
                    specs.push(ParamSpec {
                        name: format!("conv{i}.w"),
                        shape: vec![*cout, *cin, k[0], k[1], k[2]],
                        bound,
                    });
                    specs.push(ParamSpec { name: format!("conv{i}.b"), shape: vec![*cout], bound });
                }
                Layer::Dense { cin, cout, .. } => {
                    let bound = (1.0 / *cin as f64).sqrt();
                    specs.push(ParamSpec {
                        name: format!("dense{i}.w"),
                        shape: vec![*cin, *cout],
                        bound,
                    });
                    specs.push(ParamSpec { name: format!("dense{i}.b"), shape: vec![*cout], bound });
                }
                Layer::Flatten => {}
            }
        }
        specs
    }

    /// Synthesize a [`VariantMeta`] (empty artifact table) so everything
    /// downstream of the meta — `ModelState::init`, checkpoints, the native
    /// engine — works with no `meta.json` on disk.
    pub fn to_meta(&self) -> VariantMeta {
        let params = self.param_specs();
        let n_parameters = params.iter().map(|p| p.numel()).sum();
        VariantMeta {
            name: self.name.clone(),
            input: self.input.to_vec(),
            outputs: self.outputs,
            n_param_arrays: params.len(),
            n_parameters,
            params,
            artifacts: BTreeMap::new(),
        }
    }

    /// Reconstruct the architecture from a variant's parameter layout.
    ///
    /// Kernel sizes live in the conv weight shapes; strides do not. The
    /// trunk rule (stride == kernel, the Conv4Xbar disjoint-patch read)
    /// fixes every conv except the last, whose stride is solved against the
    /// first dense layer's fan-in. Fails loudly on layouts outside the
    /// conv*-flatten-dense* family.
    pub fn from_meta(meta: &VariantMeta) -> Result<Arch> {
        anyhow::ensure!(meta.input.len() == 4, "expected rank-4 input, got {:?}", meta.input);
        anyhow::ensure!(meta.params.len() % 2 == 0, "expected (weight, bias) parameter pairs");
        let input = [meta.input[0], meta.input[1], meta.input[2], meta.input[3]];

        // Pass 1: type each (weight, bias) pair.
        enum Raw {
            Conv { cout: usize, cin: usize, k: [usize; 3] },
            Dense { cin: usize, cout: usize },
        }
        let mut raw = Vec::new();
        for pair in meta.params.chunks(2) {
            let (w, b) = (&pair[0], &pair[1]);
            anyhow::ensure!(b.shape.len() == 1, "'{}' is not a bias vector", b.name);
            match w.shape.len() {
                5 => {
                    anyhow::ensure!(b.shape[0] == w.shape[0], "'{}' bias/cout mismatch", b.name);
                    raw.push(Raw::Conv {
                        cout: w.shape[0],
                        cin: w.shape[1],
                        k: [w.shape[2], w.shape[3], w.shape[4]],
                    });
                }
                2 => {
                    anyhow::ensure!(b.shape[0] == w.shape[1], "'{}' bias/cout mismatch", b.name);
                    raw.push(Raw::Dense { cin: w.shape[0], cout: w.shape[1] });
                }
                _ => bail!("'{}' rank {} is neither conv nor dense", w.name, w.shape.len()),
            }
        }
        let n_conv = raw.iter().take_while(|r| matches!(r, Raw::Conv { .. })).count();
        anyhow::ensure!(
            raw[n_conv..].iter().all(|r| matches!(r, Raw::Dense { .. })),
            "parameter layout is not conv*-then-dense*"
        );
        let first_dense_cin = raw[n_conv..].first().map(|r| match r {
            Raw::Dense { cin, .. } => *cin,
            Raw::Conv { .. } => unreachable!(),
        });

        // Pass 2: assign strides while tracking the spatial shape.
        let mut layers = Vec::with_capacity(raw.len() + 1);
        let mut c = input[0];
        let mut spatial = [input[1], input[2], input[3]];
        for (j, r) in raw.iter().enumerate() {
            match r {
                Raw::Conv { cout, cin, k } => {
                    anyhow::ensure!(*cin == c, "conv {j}: cin {cin} != incoming {c}");
                    let s = if j + 1 < n_conv {
                        *k // trunk: disjoint patches
                    } else {
                        match first_dense_cin {
                            None => *k,
                            Some(flat) => solve_last_stride(spatial, *k, *cout, flat)
                                .with_context(|| format!("conv {j} (last before flatten)"))?,
                        }
                    };
                    spatial = conv_out_shape(spatial, *k, s)
                        .with_context(|| format!("conv {j}: {k:?}/{s:?} on {spatial:?}"))?;
                    c = *cout;
                    layers.push(Layer::Conv { cin: *cin, cout: *cout, k: *k, s, celu: true });
                }
                Raw::Dense { cin, cout } => {
                    if j == n_conv {
                        layers.push(Layer::Flatten);
                        c = c * spatial[0] * spatial[1] * spatial[2];
                    }
                    anyhow::ensure!(*cin == c, "dense {j}: cin {cin} != incoming {c}");
                    let last = j + 1 == raw.len();
                    layers.push(Layer::Dense { cin: *cin, cout: *cout, celu: !last });
                    c = *cout;
                }
            }
        }
        anyhow::ensure!(
            c == meta.outputs,
            "reconstructed width {c} != meta outputs {}",
            meta.outputs
        );
        let arch = Arch { name: meta.name.clone(), input, outputs: meta.outputs, layers };
        let specs = arch.param_specs();
        anyhow::ensure!(specs.len() == meta.params.len(), "parameter count drifted");
        for (a, b) in specs.iter().zip(&meta.params) {
            anyhow::ensure!(a.shape == b.shape, "'{}' shape {:?} != meta {:?}", b.name, a.shape, b.shape);
        }
        Ok(arch)
    }
}

/// VALID-padding output shape: `floor((in - k) / s) + 1` per dim.
fn conv_out_shape(inp: [usize; 3], k: [usize; 3], s: [usize; 3]) -> Result<[usize; 3]> {
    let mut out = [0usize; 3];
    for d in 0..3 {
        anyhow::ensure!(s[d] >= 1, "stride {:?} has a zero component", s);
        anyhow::ensure!(k[d] >= 1 && k[d] <= inp[d], "kernel {:?} exceeds input {:?}", k, inp);
        out[d] = (inp[d] - k[d]) / s[d] + 1;
    }
    Ok(out)
}

/// Solve the last conv's stride so that `cout * prod(out_spatial)` equals
/// the first dense layer's fan-in. Dims with `k == 1` keep stride 1; dims
/// fully covered by the kernel produce a single patch for any stride; at
/// most one remaining dim may need solving.
fn solve_last_stride(inp: [usize; 3], k: [usize; 3], cout: usize, flat: usize) -> Result<[usize; 3]> {
    anyhow::ensure!(flat % cout == 0, "flatten size {flat} not divisible by cout {cout}");
    let target = flat / cout;
    let mut s = [0usize; 3];
    let mut known = 1usize;
    let mut free: Option<usize> = None;
    for d in 0..3 {
        if k[d] == 1 {
            s[d] = 1;
            known *= inp[d];
        } else if k[d] == inp[d] {
            // Kernel covers the whole dim: a single patch for any stride;
            // arch.py writes stride 1 here (cfg_a/small last conv).
            s[d] = 1;
        } else if free.is_none() {
            free = Some(d);
        } else {
            bail!("stride is ambiguous: two unconstrained dims in kernel {k:?} on {inp:?}");
        }
    }
    match free {
        None => {
            anyhow::ensure!(known == target, "spatial {known} != required {target}");
        }
        Some(d) => {
            anyhow::ensure!(target % known == 0, "required {target} not divisible by {known}");
            let need = target / known;
            anyhow::ensure!(need >= 1, "need at least one output position");
            let span = inp[d] - k[d];
            let candidates: Vec<usize> =
                (1..=inp[d]).filter(|&cand| span / cand + 1 == need).collect();
            match (candidates.as_slice(), need) {
                ([], _) => bail!("no stride yields {need} outputs from in {} k {}", inp[d], k[d]),
                // need == 1 reads the single patch at offset 0 whatever the
                // stride is — every candidate is semantically identical.
                (_, 1) => s[d] = candidates[0],
                ([only], _) => s[d] = *only,
                // Distinct strides with the same output count sample
                // *different* patches; guessing would serve silently wrong
                // predictions. meta.json does not record strides, so refuse.
                (many, _) => bail!(
                    "stride is ambiguous: {many:?} all yield {need} outputs from in {} k {} \
                     (use a built-in architecture, or record strides in the meta)",
                    inp[d],
                    k[d]
                ),
            }
        }
    }
    Ok(s)
}

/// Load the variant's metadata from `dir/meta.json` when present, else fall
/// back to the built-in architecture (native-only deployments need no
/// artifacts at all).
pub fn load_or_builtin_meta(dir: &Path, variant: &str) -> Result<VariantMeta> {
    if dir.join("meta.json").exists() {
        Ok(Meta::load(dir)?.variant(variant)?.clone())
    } else {
        Ok(Arch::for_variant(variant)?.to_meta())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_archs_validate_with_expected_flatten() {
        for (name, flat, outputs) in [("small", 64, 1), ("cfg_a", 128, 1), ("cfg_b", 256, 4)] {
            let a = Arch::for_variant(name).unwrap();
            assert_eq!(a.validate().unwrap(), flat, "{name}");
            assert_eq!(a.outputs, outputs, "{name}");
        }
        assert!(Arch::for_variant("nope").is_err());
    }

    #[test]
    fn param_spec_names_match_python_layout() {
        // small: 4 convs (layers 0-3), flatten (4), dense 5/6/7.
        let a = Arch::for_variant("small").unwrap();
        let names: Vec<String> = a.param_specs().iter().map(|s| s.name.clone()).collect();
        assert_eq!(names[0], "conv0.w");
        assert_eq!(names[7], "conv3.b");
        assert_eq!(names[8], "dense5.w");
        assert_eq!(names[13], "dense7.b");
        let meta = a.to_meta();
        assert_eq!(meta.n_param_arrays, 14); // 4 convs + 3 denses, (w, b) each
        assert_eq!(meta.n_parameters, meta.params.iter().map(|p| p.numel()).sum::<usize>());
    }

    #[test]
    fn from_meta_roundtrips_builtin_archs() {
        // The stride-inference path must recover every built-in arch
        // exactly — including cfg_b's non-kernel last-conv stride (1,1,2).
        for name in ["small", "cfg_a", "cfg_b"] {
            let a = Arch::for_variant(name).unwrap();
            let back = Arch::from_meta(&a.to_meta()).unwrap();
            assert_eq!(a, back, "{name}");
        }
    }

    #[test]
    fn with_extra_outputs_widens_only_the_last_dense() {
        let a = Arch::for_variant("small").unwrap();
        let wide = a.with_extra_outputs(2).unwrap();
        assert_eq!(wide.outputs, a.outputs + 2);
        assert_eq!(wide.name, a.name);
        assert_eq!(wide.layers.len(), a.layers.len());
        match (wide.layers.last().unwrap(), a.layers.last().unwrap()) {
            (Layer::Dense { cout: w, cin: wi, .. }, Layer::Dense { cout: b, cin: bi, .. }) => {
                assert_eq!(*w, b + 2);
                assert_eq!(wi, bi, "fan-in unchanged");
            }
            other => panic!("unexpected layers {other:?}"),
        }
        wide.validate().unwrap();
        // Zero extra heads is the identity.
        assert_eq!(a.with_extra_outputs(0).unwrap(), a);
    }

    #[test]
    fn from_meta_rejects_foreign_layouts() {
        let mut meta = Arch::for_variant("small").unwrap().to_meta();
        meta.params[0].shape = vec![16, 2, 1]; // rank-3 weight
        assert!(Arch::from_meta(&meta).is_err());
        let mut meta2 = Arch::for_variant("small").unwrap().to_meta();
        meta2.outputs = 9;
        assert!(Arch::from_meta(&meta2).is_err());
    }

    #[test]
    fn from_meta_refuses_ambiguous_last_stride() {
        // in=6, k=2, need 2 outputs: strides 3 and 4 both give
        // floor(4/s)+1 == 2 but sample different patches — must bail, not
        // guess (meta.json does not record strides).
        let spec = |name: &str, shape: Vec<usize>| ParamSpec { name: name.into(), shape, bound: 0.5 };
        let meta = VariantMeta {
            name: "ambig".into(),
            input: vec![1, 1, 1, 6],
            outputs: 1,
            n_param_arrays: 4,
            n_parameters: 2 + 1 + 2 + 1,
            params: vec![
                spec("conv0.w", vec![1, 1, 1, 1, 2]),
                spec("conv0.b", vec![1]),
                spec("dense2.w", vec![2, 1]),
                spec("dense2.b", vec![1]),
            ],
            artifacts: BTreeMap::new(),
        };
        let err = Arch::from_meta(&meta).unwrap_err();
        assert!(format!("{err:#}").contains("ambiguous"), "{err:#}");
    }

    #[test]
    fn builtin_meta_fallback_without_artifacts() {
        let dir = std::env::temp_dir().join(format!("semarch_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let meta = load_or_builtin_meta(&dir, "small").unwrap();
        assert_eq!(meta.input, vec![2, 2, 16, 2]);
        assert!(meta.artifacts.is_empty());
        assert!(load_or_builtin_meta(&dir, "huge").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
