//! Multi-checkpoint registry: several named [`NativeEngine`]s behind one
//! [`EmulatorBackend`].
//!
//! The paper replaces SPICE with a regressor *per analog computing block*;
//! a deployment therefore wants many `(architecture, checkpoint)` pairs —
//! device corners, non-ideality scenarios, block geometries — servable
//! from one process. The registry is that collection: variants are
//! registered under deployment-local labels (which need not match the
//! architecture name — `"cfg_a_harsh"` can wrap the `cfg_a` network), and
//! the batcher addresses them by [`VariantId`] through the v2 backend
//! contract.

use anyhow::{Context, Result};

use crate::model::ModelState;
use crate::runtime::VariantMeta;

use super::engine::NativeEngine;
use super::{BackendKind, EmulatorBackend, VariantId, VariantShape};

/// One or more named native engines served through a single backend.
#[derive(Default)]
pub struct NativeRegistry {
    engines: Vec<NativeEngine>,
    shapes: Vec<VariantShape>,
}

impl NativeRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pack `state` for `meta`'s architecture and serve it under `name`.
    /// Labels are deployment-local: they must be unique within the
    /// registry but are otherwise free (the architecture name lives in
    /// `meta`). Returns the new variant's id.
    pub fn register(
        &mut self,
        name: &str,
        meta: &VariantMeta,
        state: &ModelState,
    ) -> Result<VariantId> {
        anyhow::ensure!(!name.is_empty(), "variant label must be non-empty");
        anyhow::ensure!(
            !self.shapes.iter().any(|s| s.name == name),
            "variant '{name}' is already registered"
        );
        let engine = NativeEngine::from_meta(meta, state)
            .with_context(|| format!("building native engine for variant '{name}'"))?;
        self.shapes.push(VariantShape {
            name: name.to_string(),
            n_features: meta.n_features(),
            n_outputs: meta.outputs,
        });
        self.engines.push(engine);
        Ok(self.engines.len() - 1)
    }

    /// Number of registered variants.
    pub fn len(&self) -> usize {
        self.engines.len()
    }

    pub fn is_empty(&self) -> bool {
        self.engines.is_empty()
    }

    /// Direct access to one variant's engine (e.g. for offline evaluation).
    pub fn engine(&self, variant: VariantId) -> Option<&NativeEngine> {
        self.engines.get(variant)
    }
}

impl EmulatorBackend for NativeRegistry {
    fn kind(&self) -> BackendKind {
        BackendKind::Native
    }

    fn variants(&self) -> &[VariantShape] {
        &self.shapes
    }

    fn forward_batch(&self, variant: VariantId, inputs: &[f32]) -> Result<Vec<f32>> {
        // `shapes` and `engines` are index-aligned; the trait's shape()
        // default provides the canonical out-of-range error.
        self.shape(variant)?;
        self.engines[variant].forward(inputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::Arch;

    #[test]
    fn registry_serves_independent_variants() {
        let small = Arch::for_variant("small").unwrap().to_meta();
        let cfg_a = Arch::for_variant("cfg_a").unwrap().to_meta();
        let s_small = ModelState::init(&small, 1);
        let s_cfg_a = ModelState::init(&cfg_a, 2);
        let mut reg = NativeRegistry::new();
        assert!(reg.is_empty());
        assert_eq!(reg.register("ideal", &small, &s_small).unwrap(), 0);
        assert_eq!(reg.register("big", &cfg_a, &s_cfg_a).unwrap(), 1);
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.kind(), BackendKind::Native);
        assert_eq!(reg.variant_id("big").unwrap(), 1);
        assert!(reg.variant_id("nope").is_err());
        assert_eq!(reg.shape(0).unwrap().n_features, 128);
        assert_eq!(reg.shape(1).unwrap().n_features, 1024); // (2, 4, 64, 2)

        // Each id answers with its own engine, matching a direct forward.
        let x_small = vec![0.3f32; 128];
        let got = reg.forward_batch(0, &x_small).unwrap();
        let want = NativeEngine::from_meta(&small, &s_small).unwrap().forward(&x_small).unwrap();
        assert_eq!(got, want);
        let x_a = vec![0.3f32; 1024];
        let got_a = reg.forward_batch(1, &x_a).unwrap();
        let want_a = NativeEngine::from_meta(&cfg_a, &s_cfg_a).unwrap().forward(&x_a).unwrap();
        assert_eq!(got_a, want_a);
        assert!(reg.forward_batch(2, &x_a).is_err());
    }

    #[test]
    fn registry_rejects_duplicate_and_empty_labels() {
        let meta = Arch::for_variant("small").unwrap().to_meta();
        let state = ModelState::init(&meta, 0);
        let mut reg = NativeRegistry::new();
        reg.register("a", &meta, &state).unwrap();
        let err = reg.register("a", &meta, &state).unwrap_err();
        assert!(format!("{err:#}").contains("already registered"), "{err:#}");
        assert!(reg.register("", &meta, &state).is_err());
        // The same *checkpoint* under two labels is fine (scenario aliases).
        reg.register("b", &meta, &state).unwrap();
        assert_eq!(reg.len(), 2);
    }
}
