//! Native inference: a zero-dependency execution engine for the SEMULATOR
//! regression network, plus the [`EmulatorBackend`] abstraction that lets
//! the serving stack choose its forward implementation per deployment.
//!
//! The paper's pitch is that a regression network answers MAC queries
//! orders of magnitude faster than SPICE — but funneling every forward
//! through the PJRT runtime caps throughput at design-space-exploration
//! scale and binds serving to compiled artifacts. This layer executes the
//! network directly from a [`crate::model::ModelState`]:
//!
//! * [`arch`] — Rust mirror of the Table-2 layer stacks, plus
//!   reconstruction from `meta.json` parameter layouts.
//! * [`kernels`] — cache-blocked f32 matmul with runtime-detected SIMD
//!   (AVX2/FMA, NEON, scalar fallback), in-kernel threading for large
//!   shapes, and fused bias+CELU epilogues; `SEMULATOR_FORCE_SCALAR=1`
//!   (or [`kernels::force_scalar`]) pins the bit-exact scalar lane.
//! * [`engine`] — [`NativeEngine`]: load-time weight packing (conv im2col
//!   gather tables, pre-transposed dense weights) and thread-parallel
//!   batched execution.
//! * [`reference`] — the naive loop-nest oracle the engine is tested
//!   against.
//! * [`registry`] — [`NativeRegistry`]: several named checkpoints behind
//!   one backend, so one process serves many variants.
//! * [`train`] — [`NativeTrainer`]: backward passes for the same kernels
//!   plus SGD with the paper's LR-halving schedule, so the full
//!   datagen→train→eval→serve loop runs with zero compiled artifacts
//!   (the `coordinator::Trainer` impl `pipeline::Experiment` defaults to).
//!
//! Backends are selected by [`BackendKind`]: the dynamic batcher
//! (`coordinator::batcher`) constructs either a [`NativeRegistry`] (one or
//! more [`NativeEngine`]s) or the PJRT-backed `runtime::PjrtBackend`
//! behind the same trait, the router records which one served each
//! request, and its shadow path can cross-check one backend against the
//! other and against golden SPICE. The trait is *variant-addressed*: every
//! forward names the served variant by [`VariantId`], so a single backend
//! (and a single batcher thread) can host several block/scenario
//! emulators — the contract `semulator::api::Deployment` is built on.

pub mod arch;
pub mod engine;
pub mod kernels;
pub mod reference;
pub mod registry;
pub mod train;

pub use arch::{load_or_builtin_meta, Arch, Layer, BUILTIN_VARIANTS};
pub use engine::NativeEngine;
pub use registry::NativeRegistry;
pub use train::NativeTrainer;

use anyhow::Result;

/// Which forward-pass implementation a deployment runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust packed-matmul engine ([`NativeEngine`]); needs no
    /// compiled artifacts, only a parameter state.
    Native,
    /// AOT-compiled HLO executed through the PJRT runtime
    /// (`runtime::PjrtBackend`); needs `make artifacts` and a real `xla`
    /// crate behind it.
    Pjrt,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "native" => Ok(Self::Native),
            "pjrt" | "xla" => Ok(Self::Pjrt),
            other => anyhow::bail!("unknown backend '{other}' (native | pjrt)"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Self::Native => "native",
            Self::Pjrt => "pjrt",
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Index of a served variant within a backend (position in
/// [`EmulatorBackend::variants`]).
pub type VariantId = usize;

/// Static per-variant shape information a backend publishes: the
/// deployment-local variant label and the sample geometry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VariantShape {
    /// Deployment-local variant label (e.g. `"cfg_a"`, `"cfg_a_harsh"`).
    pub name: String,
    /// Normalized features per sample.
    pub n_features: usize,
    /// Outputs (MAC voltages) per sample.
    pub n_outputs: usize,
}

/// A batched, variant-addressed forward-pass implementation the serving
/// stack can drive (v2 contract).
///
/// One backend serves one or more *named variants* — independent
/// (architecture, checkpoint) pairs — so a single process (and a single
/// batcher thread) can host several block/scenario emulators at once.
/// Every forward names its variant by [`VariantId`], an index into
/// [`variants`](Self::variants).
///
/// Implementations own everything they need (parameters, compiled
/// executables, scratch policy). They are constructed *inside* the thread
/// that runs them — the PJRT handles are not `Send` — so the trait
/// deliberately carries no `Send` bound. [`NativeRegistry`] is the
/// multi-variant implementation; `runtime::PjrtBackend` adapts via a
/// single-variant shim (always exactly one entry in `variants()`).
pub trait EmulatorBackend {
    /// Which implementation this is (for metrics/routing labels).
    fn kind(&self) -> BackendKind;

    /// The named variants this backend serves; [`VariantId`]s index this
    /// slice. Never empty for a servable backend.
    fn variants(&self) -> &[VariantShape];

    /// Largest batch worth submitting in one call, if the implementation
    /// has a preference (e.g. the largest compiled PJRT batch shape).
    /// `None` means unbounded.
    fn max_batch(&self) -> Option<usize> {
        None
    }

    /// Run `inputs` (`k * n_features`, batch-major, any `k >= 1`) through
    /// the given variant and return `k * n_outputs` predictions.
    /// Implementations pad internally if they only support fixed shapes.
    fn forward_batch(&self, variant: VariantId, inputs: &[f32]) -> Result<Vec<f32>>;

    /// Shape of one served variant (errors on an out-of-range id).
    fn shape(&self, variant: VariantId) -> Result<&VariantShape> {
        self.variants().get(variant).ok_or_else(|| {
            anyhow::anyhow!(
                "variant id {variant} out of range ({} variant(s) served)",
                self.variants().len()
            )
        })
    }

    /// Resolve a variant label to its [`VariantId`].
    fn variant_id(&self, name: &str) -> Result<VariantId> {
        self.variants().iter().position(|s| s.name == name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown variant '{name}' (serving: {})",
                self.variants().iter().map(|s| s.name.as_str()).collect::<Vec<_>>().join(", ")
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_parses_and_prints() {
        assert_eq!(BackendKind::parse("native").unwrap(), BackendKind::Native);
        assert_eq!(BackendKind::parse("pjrt").unwrap(), BackendKind::Pjrt);
        assert_eq!(BackendKind::parse("xla").unwrap(), BackendKind::Pjrt);
        assert!(BackendKind::parse("tpu").is_err());
        assert_eq!(BackendKind::Native.to_string(), "native");
        assert_eq!(BackendKind::Pjrt.as_str(), "pjrt");
    }
}
