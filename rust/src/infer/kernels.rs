//! Packed f32 matmul and fused bias+activation kernels for the native
//! inference engine, with runtime-selected SIMD and in-kernel threading.
//!
//! Both matmul operands are laid out so the inner loop is a dot product
//! of two contiguous slices: activations/patches row-major `(M, K)`,
//! weights pre-transposed to `(N, K)` at engine-build time. The core is
//! cache-blocked over output columns ([`NC`]-wide strips of the packed
//! weight stay hot across activation rows) and register-tiled four
//! output columns per pass.
//!
//! # ISA dispatch
//!
//! The instruction set is detected once per process ([`detected_isa`]):
//! AVX2+FMA on x86_64, NEON on aarch64, scalar everywhere else — all via
//! `std::arch`, zero dependencies. `SEMULATOR_FORCE_SCALAR=1` in the
//! environment pins the whole process to the scalar path;
//! [`force_scalar`] pins the *current thread* for the guard's lifetime
//! (tests and bench lanes). Every matmul entry point reads the effective
//! ISA once ([`active_isa`]) and threads it by value into any worker
//! threads it spawns, and counts one `kernel_simd` obs tick per call
//! dispatched to a vector ISA — `semulator stats` and the Prometheus
//! exposition show which path ran.
//!
//! # Numerics contract
//!
//! The scalar path runs per-output summation sequentially over `k`,
//! matching the naive reference order bit-for-bit — the forced-scalar
//! lane in CI regresses against that exactly. The SIMD dot kernels
//! accumulate in 8 (AVX2) / 4 (NEON) partial lanes reduced at the end,
//! so they match the scalar path to a *relative* tolerance (≤ 1e-5; the
//! parity tests below pin it). The accumulate kernels and the fused
//! epilogues preserve per-output evaluation order apart from FMA
//! contraction. In-kernel threading splits disjoint output rows whose
//! per-row order never depends on the worker count, so results are
//! bit-identical across thread counts for a fixed ISA.
//!
//! # Threading
//!
//! Calls above [`PAR_FLOPS`] (`2·m·n·k`) fan output-row blocks over
//! [`crate::util::parallel_chunks_mut`] scoped threads (capped by the
//! `*_with` worker argument; the plain entry points cap at
//! [`crate::util::default_workers`]) and run under a `kernel.*` obs
//! span. Small calls stay inline — no spawn, no span, no lock.

use crate::obs::counters;

/// CELU alpha, fixed to 1 like `python/compile/arch.py::CELU_ALPHA`.
pub const CELU_ALPHA: f32 = 1.0;

/// CELU with alpha = 1: `x` for `x >= 0`, `exp(x) - 1` below.
#[inline]
pub fn celu(x: f32) -> f32 {
    if x >= 0.0 {
        x
    } else {
        x.exp_m1()
    }
}

/// Column-block width: one `(NC, k)` strip of the packed weight is
/// streamed per activation row, small enough to stay L1/L2-resident.
const NC: usize = 64;

/// `2·m·n·k` FLOP threshold above which a matmul fans out worker threads.
/// Below it (every per-sample conv GEMM, the campaign-sized trainer
/// steps) threads cost more than they save.
const PAR_FLOPS: u64 = 4_000_000;

/// Which vector instruction set the kernels dispatch to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    /// Portable scalar loops, reference summation order.
    Scalar,
    /// x86_64 AVX2 + FMA (8 f32 lanes), runtime-detected.
    Avx2,
    /// aarch64 NEON (4 f32 lanes), baseline on that target.
    Neon,
}

impl Isa {
    /// Stable lowercase label (`scalar` / `avx2` / `neon`) for stats and
    /// bench lanes.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }
}

/// Process-wide ISA: detected once, `SEMULATOR_FORCE_SCALAR` wins.
pub fn detected_isa() -> Isa {
    static DETECTED: std::sync::OnceLock<Isa> = std::sync::OnceLock::new();
    *DETECTED.get_or_init(|| {
        let forced = std::env::var_os("SEMULATOR_FORCE_SCALAR")
            .is_some_and(|v| !v.is_empty() && v != "0");
        if forced {
            return Isa::Scalar;
        }
        #[cfg(target_arch = "x86_64")]
        {
            if std::is_x86_feature_detected!("avx2") && std::is_x86_feature_detected!("fma") {
                return Isa::Avx2;
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            return Isa::Neon;
        }
        #[allow(unreachable_code)]
        Isa::Scalar
    })
}

thread_local! {
    static TLS_FORCE_SCALAR: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Pins the calling thread to the scalar kernels while alive; restores
/// the previous state (nestable) on drop. Worker threads a kernel spawns
/// inherit the forcing because the ISA is resolved once at kernel entry,
/// and [`crate::util::parallel_map`] / [`crate::util::parallel_chunks_mut`]
/// re-apply it on their workers — so forcing composes with engine-level
/// batch threading too.
pub struct ScalarGuard {
    prev: bool,
}

/// Force the scalar path on this thread for the guard's lifetime.
pub fn force_scalar() -> ScalarGuard {
    ScalarGuard { prev: TLS_FORCE_SCALAR.with(|c| c.replace(true)) }
}

/// Whether this thread currently forces the scalar path — captured by the
/// parallel helpers so worker threads inherit the forcing.
pub(crate) fn thread_forces_scalar() -> bool {
    TLS_FORCE_SCALAR.with(|c| c.get())
}

/// Re-apply a captured force state on a worker thread (RAII like
/// [`force_scalar`]).
pub(crate) fn inherit_force_scalar(state: bool) -> ScalarGuard {
    ScalarGuard { prev: TLS_FORCE_SCALAR.with(|c| c.replace(state)) }
}

impl Drop for ScalarGuard {
    fn drop(&mut self) {
        let prev = self.prev;
        TLS_FORCE_SCALAR.with(|c| c.set(prev));
    }
}

/// The ISA a kernel called from this thread will dispatch to.
pub fn active_isa() -> Isa {
    if TLS_FORCE_SCALAR.with(|c| c.get()) {
        Isa::Scalar
    } else {
        detected_isa()
    }
}

/// Count one `(m, n, k)` logical matmul against the obs work counters:
/// `2·m·n·k` FLOPs and the f32 bytes of all three operands. Callers make
/// exactly one kernel call per logical matmul (worker threads split rows
/// *inside* the call), so both totals are invariant across worker
/// counts. A call dispatched to a vector ISA also ticks `kernel_simd`.
#[inline]
fn count_matmul(m: usize, n: usize, k: usize, isa: Isa) {
    counters::add_kernel_flops(2 * (m as u64) * (n as u64) * (k as u64));
    counters::add_kernel_bytes(4 * ((m * k) + (n * k) + (m * n)) as u64);
    if isa != Isa::Scalar {
        counters::add_kernel_simd(1);
    }
}

/// Worker count for a kernel of `work = 2·m·n·k` FLOPs: one worker per
/// [`PAR_FLOPS`] of work, capped by the caller's budget.
#[inline]
fn kernel_workers(work: u64, cap: usize) -> usize {
    if work < PAR_FLOPS || cap <= 1 {
        1
    } else {
        cap.min((work / PAR_FLOPS) as usize + 1)
    }
}

// ---------------------------------------------------------------------------
// matmul_nt: out[i, j] = dot(a[i, :], bt[j, :])
// ---------------------------------------------------------------------------

/// `out[i, j] = dot(a[i, :], bt[j, :])` with `a: (m, k)` row-major and
/// `bt: (n, k)` row-major (i.e. the logical `(k, n)` right operand stored
/// transposed). Threads itself over output rows when large (capped at
/// [`crate::util::default_workers`]); see [`matmul_nt_with`] to bound the
/// fan-out.
pub fn matmul_nt(a: &[f32], bt: &[f32], m: usize, n: usize, k: usize, out: &mut [f32]) {
    matmul_nt_with(a, bt, m, n, k, out, crate::util::default_workers());
}

/// [`matmul_nt`] with an explicit worker-thread cap (`1` = stay inline:
/// what per-sample conv GEMMs inside an already-parallel batch loop use).
pub fn matmul_nt_with(
    a: &[f32],
    bt: &[f32],
    m: usize,
    n: usize,
    k: usize,
    out: &mut [f32],
    max_workers: usize,
) {
    assert_eq!(a.len(), m * k, "lhs size");
    assert_eq!(bt.len(), n * k, "packed rhs size");
    assert_eq!(out.len(), m * n, "out size");
    let isa = active_isa();
    count_matmul(m, n, k, isa);
    if m == 0 || n == 0 {
        return;
    }
    let work = 2 * (m as u64) * (n as u64) * (k as u64);
    let workers = kernel_workers(work, max_workers).min(m);
    if workers <= 1 {
        matmul_nt_rows(a, bt, m, n, k, out, isa);
        return;
    }
    let _sp = crate::obs::span("kernel.matmul_nt");
    let rows_per = m.div_ceil(workers);
    crate::util::parallel_chunks_mut(out, rows_per * n, workers, |ci, chunk| {
        let base = ci * rows_per;
        let rows = chunk.len() / n;
        matmul_nt_rows(&a[base * k..(base + rows) * k], bt, rows, n, k, chunk, isa);
    });
}

/// Serial column-blocked core over a contiguous row range.
fn matmul_nt_rows(a: &[f32], bt: &[f32], m: usize, n: usize, k: usize, out: &mut [f32], isa: Isa) {
    for jb in (0..n).step_by(NC) {
        let je = (jb + NC).min(n);
        for i in 0..m {
            let ar = &a[i * k..(i + 1) * k];
            let or = &mut out[i * n..(i + 1) * n];
            let mut j = jb;
            while j + 4 <= je {
                let b0 = &bt[j * k..(j + 1) * k];
                let b1 = &bt[(j + 1) * k..(j + 2) * k];
                let b2 = &bt[(j + 2) * k..(j + 3) * k];
                let b3 = &bt[(j + 3) * k..(j + 4) * k];
                let (s0, s1, s2, s3) = match isa {
                    #[cfg(target_arch = "x86_64")]
                    Isa::Avx2 => unsafe { dot4_avx2(ar, b0, b1, b2, b3) },
                    #[cfg(target_arch = "aarch64")]
                    Isa::Neon => unsafe { dot4_neon(ar, b0, b1, b2, b3) },
                    _ => dot4_scalar(ar, b0, b1, b2, b3),
                };
                or[j] = s0;
                or[j + 1] = s1;
                or[j + 2] = s2;
                or[j + 3] = s3;
                j += 4;
            }
            while j < je {
                let br = &bt[j * k..(j + 1) * k];
                or[j] = match isa {
                    #[cfg(target_arch = "x86_64")]
                    Isa::Avx2 => unsafe { dot1_avx2(ar, br) },
                    #[cfg(target_arch = "aarch64")]
                    Isa::Neon => unsafe { dot1_neon(ar, br) },
                    _ => dot1_scalar(ar, br),
                };
                j += 1;
            }
        }
    }
}

#[inline]
fn dot1_scalar(ar: &[f32], br: &[f32]) -> f32 {
    let mut s = 0.0f32;
    for (av, bv) in ar.iter().zip(br) {
        s += av * bv;
    }
    s
}

/// Four sequential-order dots sharing one streamed activation row —
/// exactly the pre-SIMD kernel, kept as the bit-exact reference lane.
#[inline]
fn dot4_scalar(ar: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) -> (f32, f32, f32, f32) {
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for (t, &av) in ar.iter().enumerate() {
        s0 += av * b0[t];
        s1 += av * b1[t];
        s2 += av * b2[t];
        s3 += av * b3[t];
    }
    (s0, s1, s2, s3)
}

// ---------------------------------------------------------------------------
// Accumulate kernels (backward pass): axpy form so the inner loop is a
// contiguous fused multiply-add — and so a zero multiplier still
// propagates `0·inf = NaN` instead of silently skipping it.
// ---------------------------------------------------------------------------

/// `y[j] += a * x[j]`. No zero-skip: `a == 0.0` must still poison the
/// accumulator when `x` carries non-finites (diverged gradients).
#[inline]
fn axpy(a: f32, x: &[f32], y: &mut [f32], isa: Isa) {
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { axpy_avx2(a, x, y) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { axpy_neon(a, x, y) },
        _ => {
            for (o, &bv) in y.iter_mut().zip(x) {
                *o += a * bv;
            }
        }
    }
}

/// `out[i, j] += dot(a[i, :], b[:, j])` with both operands in *logical*
/// row-major layout: `a: (m, k)`, `b: (k, n)`. The accumulate form the
/// backward pass wants for weight gradients (`dW += dOutᵀ-shaped
/// products`), streaming `b` row-wise so the inner loop is contiguous.
/// Non-finite contributions propagate even under a zero multiplier.
pub fn matmul_nn_acc(a: &[f32], b: &[f32], m: usize, n: usize, k: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k, "lhs size");
    assert_eq!(b.len(), k * n, "rhs size");
    assert_eq!(out.len(), m * n, "out size");
    let isa = active_isa();
    count_matmul(m, n, k, isa);
    if m == 0 || n == 0 {
        return;
    }
    let work = 2 * (m as u64) * (n as u64) * (k as u64);
    let workers = kernel_workers(work, crate::util::default_workers()).min(m);
    if workers <= 1 {
        nn_acc_rows(a, b, m, n, k, out, isa);
        return;
    }
    let _sp = crate::obs::span("kernel.matmul_nn_acc");
    let rows_per = m.div_ceil(workers);
    crate::util::parallel_chunks_mut(out, rows_per * n, workers, |ci, chunk| {
        let base = ci * rows_per;
        let rows = chunk.len() / n;
        nn_acc_rows(&a[base * k..(base + rows) * k], b, rows, n, k, chunk, isa);
    });
}

fn nn_acc_rows(a: &[f32], b: &[f32], m: usize, n: usize, k: usize, out: &mut [f32], isa: Isa) {
    for i in 0..m {
        let ar = &a[i * k..(i + 1) * k];
        let or = &mut out[i * n..(i + 1) * n];
        for (t, &av) in ar.iter().enumerate() {
            axpy(av, &b[t * n..(t + 1) * n], or, isa);
        }
    }
}

/// `out[t, j] += dot(a[:, t], b[:, j])` — the `aᵀ b` accumulate with
/// `a: (m, k)` and `b: (m, n)` row-major, producing `(k, n)`. This is the
/// dense weight gradient `dW += xᵀ · dY`. Non-finite contributions
/// propagate even under a zero multiplier. Threads over disjoint output
/// (`t`) row blocks when large; per-output accumulation order over the
/// batch is fixed, so results don't depend on the worker count.
pub fn matmul_tn_acc(a: &[f32], b: &[f32], m: usize, n: usize, k: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k, "lhs size");
    assert_eq!(b.len(), m * n, "rhs size");
    assert_eq!(out.len(), k * n, "out size");
    let isa = active_isa();
    count_matmul(m, n, k, isa);
    if n == 0 || k == 0 {
        return;
    }
    let work = 2 * (m as u64) * (n as u64) * (k as u64);
    let workers = kernel_workers(work, crate::util::default_workers()).min(k);
    if workers <= 1 {
        tn_acc_tslice(a, b, m, n, k, 0, out, isa);
        return;
    }
    let _sp = crate::obs::span("kernel.matmul_tn_acc");
    let t_per = k.div_ceil(workers);
    crate::util::parallel_chunks_mut(out, t_per * n, workers, |ci, chunk| {
        tn_acc_tslice(a, b, m, n, k, ci * t_per, chunk, isa);
    });
}

/// Accumulate output rows `t0 .. t0 + out_slice.len()/n` of the `aᵀ b`
/// product; each worker owns a disjoint `t` range.
fn tn_acc_tslice(
    a: &[f32],
    b: &[f32],
    m: usize,
    n: usize,
    k: usize,
    t0: usize,
    out_slice: &mut [f32],
    isa: Isa,
) {
    let tr = out_slice.len() / n;
    for i in 0..m {
        let br = &b[i * n..(i + 1) * n];
        for dt in 0..tr {
            let av = a[i * k + t0 + dt];
            axpy(av, br, &mut out_slice[dt * n..(dt + 1) * n], isa);
        }
    }
}

// ---------------------------------------------------------------------------
// Fused bias + CELU epilogues
// ---------------------------------------------------------------------------

/// Fused epilogue for channel-major conv output `(rows = channels, cols =
/// spatial positions)`: add `bias[r]` to every element of row `r`, then
/// optionally CELU — one pass over the buffer. Vector groups with any
/// negative (or NaN) lane fall back to the scalar CELU, so the result is
/// bit-exact with the scalar path on every ISA.
pub fn bias_celu_rows(out: &mut [f32], rows: usize, cols: usize, bias: &[f32], apply_celu: bool) {
    assert_eq!(out.len(), rows * cols);
    assert_eq!(bias.len(), rows);
    let isa = active_isa();
    for r in 0..rows {
        let b = bias[r];
        let row = &mut out[r * cols..(r + 1) * cols];
        match isa {
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => unsafe { bias_celu_splat_avx2(row, b, apply_celu) },
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => unsafe { bias_celu_splat_neon(row, b, apply_celu) },
            _ => bias_celu_splat_scalar(row, b, apply_celu),
        }
    }
}

/// Fused epilogue for batch-major dense output `(rows = batch, cols =
/// units)`: add `bias[c]` per column, then optionally CELU. Same
/// bit-exactness contract as [`bias_celu_rows`].
pub fn bias_celu_cols(out: &mut [f32], rows: usize, cols: usize, bias: &[f32], apply_celu: bool) {
    assert_eq!(out.len(), rows * cols);
    assert_eq!(bias.len(), cols);
    let isa = active_isa();
    for r in 0..rows {
        let row = &mut out[r * cols..(r + 1) * cols];
        match isa {
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => unsafe { bias_celu_vec_avx2(row, bias, apply_celu) },
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => unsafe { bias_celu_vec_neon(row, bias, apply_celu) },
            _ => bias_celu_vec_scalar(row, bias, apply_celu),
        }
    }
}

#[inline]
fn bias_celu_splat_scalar(row: &mut [f32], b: f32, apply_celu: bool) {
    for v in row {
        let z = *v + b;
        *v = if apply_celu { celu(z) } else { z };
    }
}

#[inline]
fn bias_celu_vec_scalar(row: &mut [f32], bias: &[f32], apply_celu: bool) {
    for (v, b) in row.iter_mut().zip(bias) {
        let z = *v + *b;
        *v = if apply_celu { celu(z) } else { z };
    }
}

/// Derivative of [`celu`] with alpha = 1, expressed in terms of the
/// *activation* `a = celu(z)`: `1` on the linear branch (`a >= 0` iff
/// `z >= 0`), else `exp(z) = a + 1`. Taking the activation instead of the
/// pre-activation lets the backward pass reuse the forward buffers.
#[inline]
pub fn celu_grad_from_act(a: f32) -> f32 {
    if a >= 0.0 {
        1.0
    } else {
        a + 1.0
    }
}

/// Pack a row-major `(k, n)` dense weight into `(n, k)` for [`matmul_nt`].
pub fn transpose_pack(w: &[f32], k: usize, n: usize) -> Vec<f32> {
    assert_eq!(w.len(), k * n);
    let mut wt = vec![0.0f32; n * k];
    for kk in 0..k {
        for nn in 0..n {
            wt[nn * k + kk] = w[kk * n + nn];
        }
    }
    wt
}

// ---------------------------------------------------------------------------
// AVX2 + FMA microkernels (x86_64, runtime-detected)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn hsum(v: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps::<1>(v);
        let s = _mm_add_ps(lo, hi);
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps::<0b01>(s, s));
        _mm_cvtss_f32(s)
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn dot4(
        ar: &[f32],
        b0: &[f32],
        b1: &[f32],
        b2: &[f32],
        b3: &[f32],
    ) -> (f32, f32, f32, f32) {
        let k = ar.len();
        let mut a0 = _mm256_setzero_ps();
        let mut a1 = _mm256_setzero_ps();
        let mut a2 = _mm256_setzero_ps();
        let mut a3 = _mm256_setzero_ps();
        let mut t = 0;
        while t + 8 <= k {
            let av = _mm256_loadu_ps(ar.as_ptr().add(t));
            a0 = _mm256_fmadd_ps(av, _mm256_loadu_ps(b0.as_ptr().add(t)), a0);
            a1 = _mm256_fmadd_ps(av, _mm256_loadu_ps(b1.as_ptr().add(t)), a1);
            a2 = _mm256_fmadd_ps(av, _mm256_loadu_ps(b2.as_ptr().add(t)), a2);
            a3 = _mm256_fmadd_ps(av, _mm256_loadu_ps(b3.as_ptr().add(t)), a3);
            t += 8;
        }
        let (mut s0, mut s1, mut s2, mut s3) = (hsum(a0), hsum(a1), hsum(a2), hsum(a3));
        while t < k {
            let av = ar[t];
            s0 += av * b0[t];
            s1 += av * b1[t];
            s2 += av * b2[t];
            s3 += av * b3[t];
            t += 1;
        }
        (s0, s1, s2, s3)
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn dot1(ar: &[f32], br: &[f32]) -> f32 {
        let k = ar.len();
        let mut acc = _mm256_setzero_ps();
        let mut t = 0;
        while t + 8 <= k {
            acc = _mm256_fmadd_ps(
                _mm256_loadu_ps(ar.as_ptr().add(t)),
                _mm256_loadu_ps(br.as_ptr().add(t)),
                acc,
            );
            t += 8;
        }
        let mut s = hsum(acc);
        while t < k {
            s += ar[t] * br[t];
            t += 1;
        }
        s
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
        let n = y.len();
        let av = _mm256_set1_ps(a);
        let mut j = 0;
        while j + 8 <= n {
            let yv = _mm256_loadu_ps(y.as_ptr().add(j));
            let xv = _mm256_loadu_ps(x.as_ptr().add(j));
            _mm256_storeu_ps(y.as_mut_ptr().add(j), _mm256_fmadd_ps(av, xv, yv));
            j += 8;
        }
        while j < n {
            y[j] += a * x[j];
            j += 1;
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn bias_celu_splat(row: &mut [f32], b: f32, apply_celu: bool) {
        let n = row.len();
        let bv = _mm256_set1_ps(b);
        let zero = _mm256_setzero_ps();
        let mut j = 0;
        while j + 8 <= n {
            let z = _mm256_add_ps(_mm256_loadu_ps(row.as_ptr().add(j)), bv);
            if apply_celu && _mm256_movemask_ps(_mm256_cmp_ps::<_CMP_LT_OQ>(z, zero)) != 0 {
                super::bias_celu_splat_scalar(&mut row[j..j + 8], b, true);
            } else {
                _mm256_storeu_ps(row.as_mut_ptr().add(j), z);
            }
            j += 8;
        }
        super::bias_celu_splat_scalar(&mut row[j..], b, apply_celu);
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn bias_celu_vec(row: &mut [f32], bias: &[f32], apply_celu: bool) {
        let n = row.len();
        let zero = _mm256_setzero_ps();
        let mut j = 0;
        while j + 8 <= n {
            let z = _mm256_add_ps(
                _mm256_loadu_ps(row.as_ptr().add(j)),
                _mm256_loadu_ps(bias.as_ptr().add(j)),
            );
            if apply_celu && _mm256_movemask_ps(_mm256_cmp_ps::<_CMP_LT_OQ>(z, zero)) != 0 {
                super::bias_celu_vec_scalar(&mut row[j..j + 8], &bias[j..j + 8], true);
            } else {
                _mm256_storeu_ps(row.as_mut_ptr().add(j), z);
            }
            j += 8;
        }
        super::bias_celu_vec_scalar(&mut row[j..], &bias[j..], apply_celu);
    }
}

#[cfg(target_arch = "x86_64")]
use avx2::{
    axpy as axpy_avx2, bias_celu_splat as bias_celu_splat_avx2, bias_celu_vec as bias_celu_vec_avx2,
    dot1 as dot1_avx2, dot4 as dot4_avx2,
};

// ---------------------------------------------------------------------------
// NEON microkernels (aarch64 baseline)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    pub(super) unsafe fn dot4(
        ar: &[f32],
        b0: &[f32],
        b1: &[f32],
        b2: &[f32],
        b3: &[f32],
    ) -> (f32, f32, f32, f32) {
        let k = ar.len();
        let mut a0 = vdupq_n_f32(0.0);
        let mut a1 = vdupq_n_f32(0.0);
        let mut a2 = vdupq_n_f32(0.0);
        let mut a3 = vdupq_n_f32(0.0);
        let mut t = 0;
        while t + 4 <= k {
            let av = vld1q_f32(ar.as_ptr().add(t));
            a0 = vfmaq_f32(a0, av, vld1q_f32(b0.as_ptr().add(t)));
            a1 = vfmaq_f32(a1, av, vld1q_f32(b1.as_ptr().add(t)));
            a2 = vfmaq_f32(a2, av, vld1q_f32(b2.as_ptr().add(t)));
            a3 = vfmaq_f32(a3, av, vld1q_f32(b3.as_ptr().add(t)));
            t += 4;
        }
        let (mut s0, mut s1, mut s2, mut s3) =
            (vaddvq_f32(a0), vaddvq_f32(a1), vaddvq_f32(a2), vaddvq_f32(a3));
        while t < k {
            let av = ar[t];
            s0 += av * b0[t];
            s1 += av * b1[t];
            s2 += av * b2[t];
            s3 += av * b3[t];
            t += 1;
        }
        (s0, s1, s2, s3)
    }

    pub(super) unsafe fn dot1(ar: &[f32], br: &[f32]) -> f32 {
        let k = ar.len();
        let mut acc = vdupq_n_f32(0.0);
        let mut t = 0;
        while t + 4 <= k {
            acc = vfmaq_f32(acc, vld1q_f32(ar.as_ptr().add(t)), vld1q_f32(br.as_ptr().add(t)));
            t += 4;
        }
        let mut s = vaddvq_f32(acc);
        while t < k {
            s += ar[t] * br[t];
            t += 1;
        }
        s
    }

    pub(super) unsafe fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
        let n = y.len();
        let av = vdupq_n_f32(a);
        let mut j = 0;
        while j + 4 <= n {
            let yv = vld1q_f32(y.as_ptr().add(j));
            let xv = vld1q_f32(x.as_ptr().add(j));
            vst1q_f32(y.as_mut_ptr().add(j), vfmaq_f32(yv, av, xv));
            j += 4;
        }
        while j < n {
            y[j] += a * x[j];
            j += 1;
        }
    }

    pub(super) unsafe fn bias_celu_splat(row: &mut [f32], b: f32, apply_celu: bool) {
        let n = row.len();
        let bv = vdupq_n_f32(b);
        let mut j = 0;
        while j + 4 <= n {
            let z = vaddq_f32(vld1q_f32(row.as_ptr().add(j)), bv);
            // NaN lanes fail the `>= 0` check and take the scalar path too.
            if apply_celu && !(vminvq_f32(z) >= 0.0) {
                super::bias_celu_splat_scalar(&mut row[j..j + 4], b, true);
            } else {
                vst1q_f32(row.as_mut_ptr().add(j), z);
            }
            j += 4;
        }
        super::bias_celu_splat_scalar(&mut row[j..], b, apply_celu);
    }

    pub(super) unsafe fn bias_celu_vec(row: &mut [f32], bias: &[f32], apply_celu: bool) {
        let n = row.len();
        let mut j = 0;
        while j + 4 <= n {
            let z = vaddq_f32(vld1q_f32(row.as_ptr().add(j)), vld1q_f32(bias.as_ptr().add(j)));
            if apply_celu && !(vminvq_f32(z) >= 0.0) {
                super::bias_celu_vec_scalar(&mut row[j..j + 4], &bias[j..j + 4], true);
            } else {
                vst1q_f32(row.as_mut_ptr().add(j), z);
            }
            j += 4;
        }
        super::bias_celu_vec_scalar(&mut row[j..], &bias[j..], apply_celu);
    }
}

#[cfg(target_arch = "aarch64")]
use neon::{
    axpy as axpy_neon, bias_celu_splat as bias_celu_splat_neon, bias_celu_vec as bias_celu_vec_neon,
    dot1 as dot1_neon, dot4 as dot4_neon,
};

#[cfg(test)]
mod tests {
    use super::*;

    /// Naive `(m, k) x (k, n)` with the right operand in *logical* layout.
    fn matmul_naive(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f32;
                for t in 0..k {
                    s += a[i * k + t] * b[t * n + j];
                }
                out[i * n + j] = s;
            }
        }
        out
    }

    fn fill(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::util::Rng::seed_from(seed);
        (0..n).map(|_| rng.range(-1.0, 1.0) as f32).collect()
    }

    /// Awkward shapes: lane tails in every dimension, k = 1, odd n.
    const SHAPES: [(usize, usize, usize); 9] = [
        (1, 1, 1),
        (2, 7, 3),
        (5, 4, 9),
        (3, 13, 1),
        (8, 8, 32),
        (4, 5, 17),
        (1, 9, 16),
        (6, 31, 33),
        (2, 66, 8),
    ];

    fn close_rel(g: f32, w: f32, ctx: &str) {
        assert!((g - w).abs() <= 1e-5 * (1.0 + w.abs()), "{ctx}: {g} vs {w}");
    }

    #[test]
    fn identity_weight_is_identity() {
        let (m, k) = (3, 5);
        let a = fill(m * k, 1);
        let mut eye = vec![0.0f32; k * k];
        for i in 0..k {
            eye[i * k + i] = 1.0;
        }
        // Identity is its own transpose; pack anyway to exercise the path.
        let eyet = transpose_pack(&eye, k, k);
        let mut out = vec![0.0f32; m * k];
        matmul_nt(&a, &eyet, m, k, k, &mut out);
        assert_eq!(out, a);
    }

    #[test]
    fn matches_naive_on_rectangular_shapes() {
        for (si, &(m, n, k)) in SHAPES.iter().enumerate() {
            let seed = 2 + si as u64;
            let a = fill(m * k, seed);
            let b = fill(k * n, seed + 100);
            let want = matmul_naive(&a, &b, m, n, k);
            let bt = transpose_pack(&b, k, n);
            let mut got = vec![0.0f32; m * n];
            matmul_nt(&a, &bt, m, n, k, &mut got);
            for (g, w) in got.iter().zip(&want) {
                close_rel(*g, *w, &format!("({m},{n},{k})"));
            }
        }
    }

    #[test]
    fn forced_scalar_matches_reference_order_exactly() {
        let _g = force_scalar();
        assert_eq!(active_isa(), Isa::Scalar);
        for (si, &(m, n, k)) in SHAPES.iter().enumerate() {
            let seed = 40 + si as u64;
            let a = fill(m * k, seed);
            let b = fill(k * n, seed + 100);
            let bt = transpose_pack(&b, k, n);
            let mut got = vec![0.0f32; m * n];
            matmul_nt(&a, &bt, m, n, k, &mut got);
            // Bit-exact: the scalar kernel keeps the naive summation order.
            assert_eq!(got, matmul_naive(&a, &b, m, n, k), "({m},{n},{k})");
        }
    }

    #[test]
    fn simd_matches_scalar_within_relative_tolerance() {
        // On hosts without a vector ISA both runs are scalar and the
        // comparison is trivially exact; with AVX2/NEON this pins the
        // documented <= 1e-5 relative parity across lane-tail shapes.
        for (si, &(m, n, k)) in SHAPES.iter().enumerate() {
            let seed = 60 + si as u64;
            let a = fill(m * k, seed);
            let b = fill(k * n, seed + 100);
            let bt = transpose_pack(&b, k, n);
            let mut simd = vec![0.0f32; m * n];
            matmul_nt(&a, &bt, m, n, k, &mut simd);
            let mut scal = vec![0.0f32; m * n];
            {
                let _g = force_scalar();
                matmul_nt(&a, &bt, m, n, k, &mut scal);
            }
            for (g, w) in simd.iter().zip(&scal) {
                close_rel(*g, *w, &format!("nt ({m},{n},{k})"));
            }

            let seedb = fill(m * n, seed + 7);
            let mut simd_nn = seedb.clone();
            matmul_nn_acc(&a, &b, m, n, k, &mut simd_nn);
            let mut scal_nn = seedb.clone();
            {
                let _g = force_scalar();
                matmul_nn_acc(&a, &b, m, n, k, &mut scal_nn);
            }
            for (g, w) in simd_nn.iter().zip(&scal_nn) {
                close_rel(*g, *w, &format!("nn ({m},{n},{k})"));
            }

            let b2 = fill(m * n, seed + 9);
            let mut simd_tn = vec![0.0f32; k * n];
            matmul_tn_acc(&a, &b2, m, n, k, &mut simd_tn);
            let mut scal_tn = vec![0.0f32; k * n];
            {
                let _g = force_scalar();
                matmul_tn_acc(&a, &b2, m, n, k, &mut scal_tn);
            }
            for (g, w) in simd_tn.iter().zip(&scal_tn) {
                close_rel(*g, *w, &format!("tn ({m},{n},{k})"));
            }
        }
    }

    #[test]
    fn epilogues_are_bit_exact_across_isas() {
        for (cols, seed) in [(1usize, 80u64), (7, 81), (8, 82), (19, 83), (64, 84)] {
            let rows = 3;
            let base = fill(rows * cols, seed);
            let bias_r = fill(rows, seed + 1);
            let bias_c = fill(cols, seed + 2);
            for apply in [false, true] {
                let mut simd = base.clone();
                bias_celu_rows(&mut simd, rows, cols, &bias_r, apply);
                let mut scal = base.clone();
                {
                    let _g = force_scalar();
                    bias_celu_rows(&mut scal, rows, cols, &bias_r, apply);
                }
                assert_eq!(simd, scal, "rows cols={cols} celu={apply}");

                let mut simd = base.clone();
                bias_celu_cols(&mut simd, rows, cols, &bias_c, apply);
                let mut scal = base.clone();
                {
                    let _g = force_scalar();
                    bias_celu_cols(&mut scal, rows, cols, &bias_c, apply);
                }
                assert_eq!(simd, scal, "cols cols={cols} celu={apply}");
            }
        }
    }

    #[test]
    fn threaded_matches_serial_bit_exactly() {
        // 2*m*n*k just above PAR_FLOPS so the auto path fans out.
        let (m, n, k) = (260, 64, 128);
        assert!(2 * (m * n * k) as u64 > PAR_FLOPS);
        let a = fill(m * k, 90);
        let b = fill(k * n, 91);
        let bt = transpose_pack(&b, k, n);
        let mut serial = vec![0.0f32; m * n];
        matmul_nt_with(&a, &bt, m, n, k, &mut serial, 1);
        let mut auto = vec![0.0f32; m * n];
        matmul_nt(&a, &bt, m, n, k, &mut auto);
        let mut four = vec![0.0f32; m * n];
        matmul_nt_with(&a, &bt, m, n, k, &mut four, 4);
        assert_eq!(serial, auto);
        assert_eq!(serial, four);
    }

    #[test]
    fn transpose_pack_roundtrip() {
        let (k, n) = (4, 3);
        let w = fill(k * n, 7);
        let wt = transpose_pack(&w, k, n);
        for kk in 0..k {
            for nn in 0..n {
                assert_eq!(wt[nn * k + kk], w[kk * n + nn]);
            }
        }
        // Packing twice returns to the original layout.
        assert_eq!(transpose_pack(&wt, n, k), w);
    }

    #[test]
    fn accumulate_matmuls_match_naive() {
        for (m, n, k, seed) in [(1, 1, 1, 11), (3, 5, 4, 12), (6, 2, 7, 13)] {
            let a = fill(m * k, seed);
            let b = fill(k * n, seed + 50);
            let want = matmul_naive(&a, &b, m, n, k);
            let mut got = fill(m * n, seed + 90); // nonzero: accumulate form
            let base = got.clone();
            matmul_nn_acc(&a, &b, m, n, k, &mut got);
            for ((g, w), o) in got.iter().zip(&want).zip(&base) {
                assert!((g - (w + o)).abs() <= 1e-5, "nn ({m},{n},{k})");
            }
            // aᵀ b against the naive product of the explicit transpose.
            let b2 = fill(m * n, seed + 70);
            let at = transpose_pack(&a, m, k); // (m, k) -> (k, m)
            let want_t = matmul_naive(&at, &b2, k, n, m);
            let mut got_t = vec![0.0f32; k * n];
            matmul_tn_acc(&a, &b2, m, n, k, &mut got_t);
            for (g, w) in got_t.iter().zip(&want_t) {
                assert!((g - w).abs() <= 1e-5, "tn ({m},{n},{k})");
            }
        }
    }

    #[test]
    fn zero_times_inf_poisons_accumulators() {
        // A zero multiplier must not skip a non-finite contribution:
        // 0 * inf = NaN has to reach the accumulator (diverged gradients
        // must surface, not vanish). Checked on both ISA paths.
        for forced in [false, true] {
            let _g = forced.then(force_scalar);
            let (m, n, k) = (1, 4, 2);
            let a = vec![0.0f32, 1.0]; // a[0] multiplies the inf row
            let mut b = vec![1.0f32; k * n];
            b[0] = f32::INFINITY;
            let mut out = vec![0.0f32; m * n];
            matmul_nn_acc(&a, &b, m, n, k, &mut out);
            assert!(out[0].is_nan(), "nn_acc forced={forced}: {out:?}");
            assert!(out[1].is_finite(), "nn_acc forced={forced}: {out:?}");

            // tn: a[:, t] holds the zero, b carries the inf.
            let a2 = vec![0.0f32, 1.0]; // (m=2, k=1)
            let mut b2 = vec![1.0f32; 2 * n];
            b2[0] = f32::NEG_INFINITY;
            let mut out2 = vec![0.0f32; n];
            matmul_tn_acc(&a2, &b2, 2, n, 1, &mut out2);
            assert!(out2[0].is_nan(), "tn_acc forced={forced}: {out2:?}");
            assert!(out2[1].is_finite(), "tn_acc forced={forced}: {out2:?}");
        }
    }

    #[test]
    fn force_scalar_guard_nests_and_restores() {
        let outer = active_isa();
        {
            let _a = force_scalar();
            assert_eq!(active_isa(), Isa::Scalar);
            {
                let _b = force_scalar();
                assert_eq!(active_isa(), Isa::Scalar);
            }
            assert_eq!(active_isa(), Isa::Scalar);
        }
        assert_eq!(active_isa(), outer);
    }

    #[test]
    fn matmuls_count_flops_and_bytes() {
        use crate::obs::counters;
        let set = std::sync::Arc::new(crate::obs::CounterSet::new());
        let _g = counters::scoped(set.clone());
        let (m, n, k) = (2, 3, 4);
        let a = fill(m * k, 21);
        let b = fill(k * n, 22);
        let bt = transpose_pack(&b, k, n);
        let mut out = vec![0.0f32; m * n];
        matmul_nt(&a, &bt, m, n, k, &mut out);
        let s = set.snapshot();
        assert_eq!(s.kernel_flops, 2 * 2 * 3 * 4);
        assert_eq!(s.kernel_bytes, 4 * (2 * 4 + 3 * 4 + 2 * 3));
        matmul_nn_acc(&a, &b, m, n, k, &mut out);
        let mut wt = vec![0.0f32; k * n];
        matmul_tn_acc(&a, &out, m, n, k, &mut wt);
        let s = set.snapshot();
        assert_eq!(s.kernel_flops, 3 * 48);
        // One kernel_simd tick per vector-dispatched call, zero when the
        // process/thread runs scalar.
        let expect_simd = if active_isa() == Isa::Scalar { 0 } else { 3 };
        assert_eq!(s.kernel_simd, expect_simd);
        {
            let _f = force_scalar();
            matmul_nt(&a, &bt, m, n, k, &mut out);
        }
        assert_eq!(set.snapshot().kernel_simd, expect_simd, "forced-scalar call must not tick");
    }

    #[test]
    fn celu_grad_matches_derivative() {
        for z in [-3.0f32, -0.7, -1e-3, 0.0, 1e-3, 2.0] {
            let a = celu(z);
            let grad = celu_grad_from_act(a);
            let h = 1e-3f32;
            let fd = (celu(z + h) - celu(z - h)) / (2.0 * h);
            assert!((grad - fd).abs() < 1e-3, "z={z}: {grad} vs fd {fd}");
        }
    }

    #[test]
    fn celu_values() {
        assert_eq!(celu(2.5), 2.5);
        assert_eq!(celu(0.0), 0.0);
        assert!((celu(-1.0) - (-1.0f32).exp_m1()).abs() < 1e-7);
        assert!(celu(-30.0) > -1.0 - 1e-6); // lower-bounded by -alpha
    }

    #[test]
    fn fused_bias_epilogues() {
        let mut rows = vec![0.0, -2.0, 1.0, -3.0]; // (2 rows, 2 cols)
        bias_celu_rows(&mut rows, 2, 2, &[1.0, -1.0], true);
        assert_eq!(rows[0], 1.0); // 0 + 1
        assert!((rows[1] - (-1.0f32).exp_m1()).abs() < 1e-7); // -2 + 1
        assert_eq!(rows[2], 0.0); // 1 - 1
        let mut cols = vec![0.0, -2.0, 1.0, -3.0]; // (2 rows, 2 cols)
        bias_celu_cols(&mut cols, 2, 2, &[1.0, -1.0], false);
        assert_eq!(cols, vec![1.0, -3.0, 2.0, -4.0]);
    }
}
