//! Packed f32 matmul and fused bias+activation kernels for the native
//! inference engine.
//!
//! Both operands are laid out so the inner loop is a dot product of two
//! contiguous slices: activations/patches row-major `(M, K)`, weights
//! pre-transposed to `(N, K)` at engine-build time. The kernel register-
//! blocks four output columns per pass so each activation row is streamed
//! once per block instead of once per column. Per-output summation runs
//! sequentially over `k`, matching the naive reference order — important
//! for the native-vs-reference parity tests.

/// CELU alpha, fixed to 1 like `python/compile/arch.py::CELU_ALPHA`.
pub const CELU_ALPHA: f32 = 1.0;

/// CELU with alpha = 1: `x` for `x >= 0`, `exp(x) - 1` below.
#[inline]
pub fn celu(x: f32) -> f32 {
    if x >= 0.0 {
        x
    } else {
        x.exp_m1()
    }
}

/// Count one `(m, n, k)` matmul against the obs work counters: 2·m·n·k
/// FLOPs (chunk-invariant) and the f32 bytes of all three operands
/// (per-call, so NOT chunk-invariant — the weight operand recounts per
/// chunk).
#[inline]
fn count_matmul(m: usize, n: usize, k: usize) {
    crate::obs::counters::add_kernel_flops(2 * (m as u64) * (n as u64) * (k as u64));
    crate::obs::counters::add_kernel_bytes(4 * ((m * k) + (n * k) + (m * n)) as u64);
}

/// `out[i, j] = dot(a[i, :], bt[j, :])` with `a: (m, k)` row-major and
/// `bt: (n, k)` row-major (i.e. the logical `(k, n)` right operand stored
/// transposed).
pub fn matmul_nt(a: &[f32], bt: &[f32], m: usize, n: usize, k: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k, "lhs size");
    assert_eq!(bt.len(), n * k, "packed rhs size");
    assert_eq!(out.len(), m * n, "out size");
    count_matmul(m, n, k);
    for i in 0..m {
        let ar = &a[i * k..(i + 1) * k];
        let or = &mut out[i * n..(i + 1) * n];
        let mut j = 0;
        while j + 4 <= n {
            let b0 = &bt[j * k..(j + 1) * k];
            let b1 = &bt[(j + 1) * k..(j + 2) * k];
            let b2 = &bt[(j + 2) * k..(j + 3) * k];
            let b3 = &bt[(j + 3) * k..(j + 4) * k];
            let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            for t in 0..k {
                let av = ar[t];
                s0 += av * b0[t];
                s1 += av * b1[t];
                s2 += av * b2[t];
                s3 += av * b3[t];
            }
            or[j] = s0;
            or[j + 1] = s1;
            or[j + 2] = s2;
            or[j + 3] = s3;
            j += 4;
        }
        while j < n {
            let br = &bt[j * k..(j + 1) * k];
            let mut s = 0.0f32;
            for t in 0..k {
                s += ar[t] * br[t];
            }
            or[j] = s;
            j += 1;
        }
    }
}

/// Fused epilogue for channel-major conv output `(rows = channels, cols =
/// spatial positions)`: add `bias[r]` to every element of row `r`, then
/// optionally CELU — one pass over the buffer.
pub fn bias_celu_rows(out: &mut [f32], rows: usize, cols: usize, bias: &[f32], apply_celu: bool) {
    assert_eq!(out.len(), rows * cols);
    assert_eq!(bias.len(), rows);
    for r in 0..rows {
        let b = bias[r];
        for v in &mut out[r * cols..(r + 1) * cols] {
            let z = *v + b;
            *v = if apply_celu { celu(z) } else { z };
        }
    }
}

/// Fused epilogue for batch-major dense output `(rows = batch, cols =
/// units)`: add `bias[c]` per column, then optionally CELU.
pub fn bias_celu_cols(out: &mut [f32], rows: usize, cols: usize, bias: &[f32], apply_celu: bool) {
    assert_eq!(out.len(), rows * cols);
    assert_eq!(bias.len(), cols);
    for r in 0..rows {
        let row = &mut out[r * cols..(r + 1) * cols];
        for (v, b) in row.iter_mut().zip(bias) {
            let z = *v + *b;
            *v = if apply_celu { celu(z) } else { z };
        }
    }
}

/// Derivative of [`celu`] with alpha = 1, expressed in terms of the
/// *activation* `a = celu(z)`: `1` on the linear branch (`a >= 0` iff
/// `z >= 0`), else `exp(z) = a + 1`. Taking the activation instead of the
/// pre-activation lets the backward pass reuse the forward buffers.
#[inline]
pub fn celu_grad_from_act(a: f32) -> f32 {
    if a >= 0.0 {
        1.0
    } else {
        a + 1.0
    }
}

/// `out[i, j] += dot(a[i, :], b[:, j])` with both operands in *logical*
/// row-major layout: `a: (m, k)`, `b: (k, n)`. The accumulate form the
/// backward pass wants for weight gradients (`dW += dOutᵀ-shaped
/// products`), streaming `b` row-wise so the inner loop is contiguous.
pub fn matmul_nn_acc(a: &[f32], b: &[f32], m: usize, n: usize, k: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k, "lhs size");
    assert_eq!(b.len(), k * n, "rhs size");
    assert_eq!(out.len(), m * n, "out size");
    count_matmul(m, n, k);
    for i in 0..m {
        let ar = &a[i * k..(i + 1) * k];
        let or = &mut out[i * n..(i + 1) * n];
        for (t, &av) in ar.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let br = &b[t * n..(t + 1) * n];
            for (o, &bv) in or.iter_mut().zip(br) {
                *o += av * bv;
            }
        }
    }
}

/// `out[t, j] += dot(a[:, t], b[:, j])` — the `aᵀ b` accumulate with
/// `a: (m, k)` and `b: (m, n)` row-major, producing `(k, n)`. This is the
/// dense weight gradient `dW += xᵀ · dY`.
pub fn matmul_tn_acc(a: &[f32], b: &[f32], m: usize, n: usize, k: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k, "lhs size");
    assert_eq!(b.len(), m * n, "rhs size");
    assert_eq!(out.len(), k * n, "out size");
    count_matmul(m, n, k);
    for i in 0..m {
        let ar = &a[i * k..(i + 1) * k];
        let br = &b[i * n..(i + 1) * n];
        for (t, &av) in ar.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let or = &mut out[t * n..(t + 1) * n];
            for (o, &bv) in or.iter_mut().zip(br) {
                *o += av * bv;
            }
        }
    }
}

/// Pack a row-major `(k, n)` dense weight into `(n, k)` for [`matmul_nt`].
pub fn transpose_pack(w: &[f32], k: usize, n: usize) -> Vec<f32> {
    assert_eq!(w.len(), k * n);
    let mut wt = vec![0.0f32; n * k];
    for kk in 0..k {
        for nn in 0..n {
            wt[nn * k + kk] = w[kk * n + nn];
        }
    }
    wt
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Naive `(m, k) x (k, n)` with the right operand in *logical* layout.
    fn matmul_naive(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f32;
                for t in 0..k {
                    s += a[i * k + t] * b[t * n + j];
                }
                out[i * n + j] = s;
            }
        }
        out
    }

    fn fill(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::util::Rng::seed_from(seed);
        (0..n).map(|_| rng.range(-1.0, 1.0) as f32).collect()
    }

    #[test]
    fn identity_weight_is_identity() {
        let (m, k) = (3, 5);
        let a = fill(m * k, 1);
        let mut eye = vec![0.0f32; k * k];
        for i in 0..k {
            eye[i * k + i] = 1.0;
        }
        // Identity is its own transpose; pack anyway to exercise the path.
        let eyet = transpose_pack(&eye, k, k);
        let mut out = vec![0.0f32; m * k];
        matmul_nt(&a, &eyet, m, k, k, &mut out);
        assert_eq!(out, a);
    }

    #[test]
    fn matches_naive_on_rectangular_shapes() {
        // Includes n not divisible by 4 (tail path) and k = 1 edge.
        for (m, n, k, seed) in [(1, 1, 1, 2), (2, 7, 3, 3), (5, 4, 9, 4), (3, 13, 1, 5), (8, 8, 32, 6)] {
            let a = fill(m * k, seed);
            let b = fill(k * n, seed + 100);
            let want = matmul_naive(&a, &b, m, n, k);
            let bt = transpose_pack(&b, k, n);
            let mut got = vec![0.0f32; m * n];
            matmul_nt(&a, &bt, m, n, k, &mut got);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() <= 1e-5, "({m},{n},{k}): {g} vs {w}");
            }
        }
    }

    #[test]
    fn transpose_pack_roundtrip() {
        let (k, n) = (4, 3);
        let w = fill(k * n, 7);
        let wt = transpose_pack(&w, k, n);
        for kk in 0..k {
            for nn in 0..n {
                assert_eq!(wt[nn * k + kk], w[kk * n + nn]);
            }
        }
        // Packing twice returns to the original layout.
        assert_eq!(transpose_pack(&wt, n, k), w);
    }

    #[test]
    fn accumulate_matmuls_match_naive() {
        for (m, n, k, seed) in [(1, 1, 1, 11), (3, 5, 4, 12), (6, 2, 7, 13)] {
            let a = fill(m * k, seed);
            let b = fill(k * n, seed + 50);
            let want = matmul_naive(&a, &b, m, n, k);
            let mut got = fill(m * n, seed + 90); // nonzero: accumulate form
            let base = got.clone();
            matmul_nn_acc(&a, &b, m, n, k, &mut got);
            for ((g, w), o) in got.iter().zip(&want).zip(&base) {
                assert!((g - (w + o)).abs() <= 1e-5, "nn ({m},{n},{k})");
            }
            // aᵀ b against the naive product of the explicit transpose.
            let b2 = fill(m * n, seed + 70);
            let at = transpose_pack(&a, m, k); // (m, k) -> (k, m)
            let want_t = matmul_naive(&at, &b2, k, n, m);
            let mut got_t = vec![0.0f32; k * n];
            matmul_tn_acc(&a, &b2, m, n, k, &mut got_t);
            for (g, w) in got_t.iter().zip(&want_t) {
                assert!((g - w).abs() <= 1e-5, "tn ({m},{n},{k})");
            }
        }
    }

    #[test]
    fn matmuls_count_flops_and_bytes() {
        use crate::obs::counters;
        let set = std::sync::Arc::new(crate::obs::CounterSet::new());
        let _g = counters::scoped(set.clone());
        let (m, n, k) = (2, 3, 4);
        let a = fill(m * k, 21);
        let b = fill(k * n, 22);
        let bt = transpose_pack(&b, k, n);
        let mut out = vec![0.0f32; m * n];
        matmul_nt(&a, &bt, m, n, k, &mut out);
        let s = set.snapshot();
        assert_eq!(s.kernel_flops, 2 * 2 * 3 * 4);
        assert_eq!(s.kernel_bytes, 4 * (2 * 4 + 3 * 4 + 2 * 3));
        matmul_nn_acc(&a, &b, m, n, k, &mut out);
        let mut wt = vec![0.0f32; k * n];
        matmul_tn_acc(&a, &out, m, n, k, &mut wt);
        assert_eq!(set.snapshot().kernel_flops, 3 * 48);
    }

    #[test]
    fn celu_grad_matches_derivative() {
        for z in [-3.0f32, -0.7, -1e-3, 0.0, 1e-3, 2.0] {
            let a = celu(z);
            let grad = celu_grad_from_act(a);
            let h = 1e-3f32;
            let fd = (celu(z + h) - celu(z - h)) / (2.0 * h);
            assert!((grad - fd).abs() < 1e-3, "z={z}: {grad} vs fd {fd}");
        }
    }

    #[test]
    fn celu_values() {
        assert_eq!(celu(2.5), 2.5);
        assert_eq!(celu(0.0), 0.0);
        assert!((celu(-1.0) - (-1.0f32).exp_m1()).abs() < 1e-7);
        assert!(celu(-30.0) > -1.0 - 1e-6); // lower-bounded by -alpha
    }

    #[test]
    fn fused_bias_epilogues() {
        let mut rows = vec![0.0, -2.0, 1.0, -3.0]; // (2 rows, 2 cols)
        bias_celu_rows(&mut rows, 2, 2, &[1.0, -1.0], true);
        assert_eq!(rows[0], 1.0); // 0 + 1
        assert!((rows[1] - (-1.0f32).exp_m1()).abs() < 1e-7); // -2 + 1
        assert_eq!(rows[2], 0.0); // 1 - 1
        let mut cols = vec![0.0, -2.0, 1.0, -3.0]; // (2 rows, 2 cols)
        bias_celu_cols(&mut cols, 2, 2, &[1.0, -1.0], false);
        assert_eq!(cols, vec![1.0, -3.0, 2.0, -4.0]);
    }
}
