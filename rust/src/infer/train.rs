//! Native training: backward passes for the packed-matmul/im2col kernels
//! plus plain SGD with the paper's LR-halving schedule — the artifact-free
//! half of the `Trainer` abstraction.
//!
//! The PJRT coordinator trainer runs an AOT-compiled Adam step and is
//! therefore unavailable wherever `make artifacts` has not run (CI, fresh
//! clones, machines without the real `xla` crate). [`NativeTrainer`]
//! closes that gap: it differentiates the exact forward pass the
//! [`NativeEngine`](super::NativeEngine) serves —
//!
//! * conv layers backpropagate through the same im2col gather tables
//!   (patch gradients scatter-add back through the table),
//! * dense layers use the `aᵀb` / `abᵀ` accumulate kernels
//!   ([`matmul_tn_acc`](super::kernels::matmul_tn_acc),
//!   [`matmul_nt`](super::kernels::matmul_nt)),
//! * CELU derivatives are recovered from the *activations* so the forward
//!   buffers double as the tape,
//!
//! and updates parameters with minibatch SGD on the mean-squared-error
//! loss under a [`LrSchedule`](crate::coordinator::LrSchedule). Gradients
//! are held to finite differences by `tests/proptests.rs`.
//!
//! Divergence stays visible: the accumulate kernels propagate non-finite
//! contributions (`0 · ∞ = NaN` by IEEE-754, never silently skipped), so
//! an `inf`/`NaN` anywhere in the gradient stream poisons the affected
//! parameter gradients instead of vanishing behind a sparsity shortcut.

use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::trainer::{
    evaluate_native, EpochLog, TrainConfig, TrainReport, Trainer,
};
use crate::datagen::Dataset;
use crate::model::ModelState;
use crate::runtime::VariantMeta;
use crate::util::Rng;

use super::arch::{Arch, Layer};
use super::kernels::{
    bias_celu_cols, bias_celu_rows, celu_grad_from_act, matmul_nn_acc, matmul_nt, matmul_tn_acc,
    transpose_pack,
};
use super::BackendKind;

/// One differentiable layer of the compiled plan. Weights stay in their
/// natural [`ModelState`] layout (they change every step); only the
/// architecture-fixed im2col gather tables are precomputed.
enum Plan {
    Conv {
        cout: usize,
        /// Patch width `Cin * kD * kH * kW`.
        k: usize,
        /// Output spatial positions per sample.
        p: usize,
        /// `p * k` sample-local source indices (see `engine.rs`).
        gather: Vec<u32>,
        celu: bool,
        in_len: usize,
        out_len: usize,
    },
    Dense {
        k: usize,
        n: usize,
        celu: bool,
    },
}

/// Artifact-free trainer: im2col/packed-matmul backward passes + SGD.
pub struct NativeTrainer {
    arch: Arch,
    meta: VariantMeta,
    plans: Vec<Plan>,
    /// Optional per-output-column loss weights (length `arch.outputs`).
    /// `None` is uniform weighting — the established single-objective MSE.
    out_weights: Option<Vec<f32>>,
}

impl NativeTrainer {
    /// Compile the backward plan for `arch`.
    pub fn new(arch: Arch) -> Result<Self> {
        arch.validate().with_context(|| format!("arch '{}'", arch.name))?;
        let meta = arch.to_meta();
        let mut plans = Vec::new();
        let mut c = arch.input[0];
        let mut dims = [arch.input[1], arch.input[2], arch.input[3]];
        for ly in &arch.layers {
            match ly {
                Layer::Conv { cin, cout, k, s, celu } => {
                    let [d_in, h_in, w_in] = dims;
                    let od = (d_in - k[0]) / s[0] + 1;
                    let oh = (h_in - k[1]) / s[1] + 1;
                    let ow = (w_in - k[2]) / s[2] + 1;
                    let kq = cin * k[0] * k[1] * k[2];
                    let p = od * oh * ow;
                    let mut gather = Vec::with_capacity(p * kq);
                    for zd in 0..od {
                        for zh in 0..oh {
                            for zw in 0..ow {
                                for ci in 0..*cin {
                                    for kd in 0..k[0] {
                                        for kh in 0..k[1] {
                                            for kw in 0..k[2] {
                                                let xi = ((ci * d_in + zd * s[0] + kd) * h_in
                                                    + zh * s[1]
                                                    + kh)
                                                    * w_in
                                                    + zw * s[2]
                                                    + kw;
                                                gather.push(xi as u32);
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                    plans.push(Plan::Conv {
                        cout: *cout,
                        k: kq,
                        p,
                        gather,
                        celu: *celu,
                        in_len: c * d_in * h_in * w_in,
                        out_len: cout * p,
                    });
                    c = *cout;
                    dims = [od, oh, ow];
                }
                Layer::Flatten => {
                    c *= dims[0] * dims[1] * dims[2];
                    dims = [1, 1, 1];
                }
                Layer::Dense { cin, cout, celu } => {
                    plans.push(Plan::Dense { k: *cin, n: *cout, celu: *celu });
                    c = *cout;
                }
            }
        }
        Ok(Self { arch, meta, plans, out_weights: None })
    }

    /// Weight the loss per output column (e.g. down-weighting the
    /// `[energy, t_settle]` auxiliary heads of a power-enabled run against
    /// the MAC columns): the objective becomes `Σ w_j·e_ij² / (b·o)`, with
    /// gradients scaled to match. Length must equal `arch.outputs`; every
    /// weight must be finite and non-negative.
    pub fn set_output_weights(&mut self, weights: Vec<f32>) -> Result<()> {
        anyhow::ensure!(
            weights.len() == self.arch.outputs,
            "got {} output weights, arch '{}' has {} outputs",
            weights.len(),
            self.arch.name,
            self.arch.outputs
        );
        anyhow::ensure!(
            weights.iter().all(|w| w.is_finite() && *w >= 0.0),
            "output weights must be finite and >= 0, got {weights:?}"
        );
        self.out_weights = Some(weights);
        Ok(())
    }

    /// The loss weight of output column `j` (1.0 when unweighted).
    fn w_out(&self, j: usize) -> f32 {
        self.out_weights.as_ref().map_or(1.0, |w| w[j])
    }

    /// Build from a variant's parameter layout (see [`Arch::from_meta`]);
    /// `meta` is kept as-is so artifact-described variants train natively.
    pub fn from_meta(meta: &VariantMeta) -> Result<Self> {
        let mut t = Self::new(Arch::from_meta(meta)?)?;
        t.meta = meta.clone();
        Ok(t)
    }

    pub fn arch(&self) -> &Arch {
        &self.arch
    }

    pub fn meta(&self) -> &VariantMeta {
        &self.meta
    }

    fn check_state(&self, state: &ModelState) -> Result<()> {
        let specs = self.arch.param_specs();
        anyhow::ensure!(
            specs.len() == state.arrays.len(),
            "state has {} parameter arrays, arch '{}' wants {}",
            state.arrays.len(),
            self.arch.name,
            specs.len()
        );
        for (spec, arr) in specs.iter().zip(&state.arrays) {
            anyhow::ensure!(spec.numel() == arr.len(), "array '{}' size mismatch", spec.name);
        }
        Ok(())
    }

    /// Forward a batch, recording every layer's activations (the tape).
    /// `acts[0]` is the input; `acts[l + 1]` is plan `l`'s output.
    fn forward_tape(&self, state: &ModelState, xb: &[f32]) -> Result<Vec<Vec<f32>>> {
        let nf = self.arch.n_features();
        anyhow::ensure!(
            !xb.is_empty() && xb.len() % nf == 0,
            "input length {} is not a nonzero multiple of {nf} features",
            xb.len()
        );
        let b = xb.len() / nf;
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(self.plans.len() + 1);
        acts.push(xb.to_vec());
        let mut pi = 0usize;
        let mut patch: Vec<f32> = Vec::new();
        for plan in &self.plans {
            let cur = acts.last().unwrap();
            let next = match plan {
                Plan::Conv { cout, k, p, gather, celu, in_len, out_len } => {
                    let (w, bias) = (&state.arrays[pi], &state.arrays[pi + 1]);
                    let mut next = vec![0.0f32; b * out_len];
                    patch.clear();
                    patch.resize(p * k, 0.0);
                    for s in 0..b {
                        let sample = &cur[s * in_len..(s + 1) * in_len];
                        for (dst, &src) in patch.iter_mut().zip(gather.iter()) {
                            *dst = sample[src as usize];
                        }
                        let out = &mut next[s * out_len..(s + 1) * out_len];
                        matmul_nt(w, &patch, *cout, *p, *k, out);
                        bias_celu_rows(out, *cout, *p, bias, *celu);
                    }
                    next
                }
                Plan::Dense { k, n, celu } => {
                    let (w, bias) = (&state.arrays[pi], &state.arrays[pi + 1]);
                    let wt = transpose_pack(w, *k, *n);
                    let mut next = vec![0.0f32; b * n];
                    matmul_nt(cur, &wt, b, *n, *k, &mut next);
                    bias_celu_cols(&mut next, b, *n, bias, *celu);
                    next
                }
            };
            acts.push(next);
            pi += 2;
        }
        Ok(acts)
    }

    /// Mean-squared-error loss of a forward pass (no gradients) — the FD
    /// oracle for the gradient checks.
    pub fn loss(&self, state: &ModelState, xb: &[f32], yb: &[f32]) -> Result<f64> {
        self.check_state(state)?;
        let acts = self.forward_tape(state, xb)?;
        let preds = acts.last().unwrap();
        anyhow::ensure!(preds.len() == yb.len(), "target length {} vs {}", yb.len(), preds.len());
        let o = self.arch.outputs;
        let mut acc = 0.0f64;
        for (idx, (p, t)) in preds.iter().zip(yb).enumerate() {
            let e = (*p - *t) as f64;
            acc += self.w_out(idx % o) as f64 * e * e;
        }
        Ok(acc / preds.len() as f64)
    }

    /// MSE loss plus the gradient of every parameter array (meta order),
    /// averaged over the batch.
    pub fn loss_and_grads(
        &self,
        state: &ModelState,
        xb: &[f32],
        yb: &[f32],
    ) -> Result<(f64, Vec<Vec<f32>>)> {
        self.check_state(state)?;
        let acts = self.forward_tape(state, xb)?;
        let preds = acts.last().unwrap();
        anyhow::ensure!(preds.len() == yb.len(), "target length {} vs {}", yb.len(), preds.len());
        let b = xb.len() / self.arch.n_features();

        let o = self.arch.outputs;
        let mut loss = 0.0f64;
        let scale = 2.0 / preds.len() as f32;
        let mut delta: Vec<f32> = preds
            .iter()
            .zip(yb)
            .enumerate()
            .map(|(idx, (p, t))| {
                let w = self.w_out(idx % o);
                let e = *p - *t;
                loss += (w as f64) * (e as f64) * (e as f64);
                scale * w * e
            })
            .collect();
        loss /= preds.len() as f64;

        let mut grads: Vec<Vec<f32>> =
            state.arrays.iter().map(|a| vec![0.0f32; a.len()]).collect();
        let mut patch: Vec<f32> = Vec::new();
        let mut dpatch: Vec<f32> = Vec::new();
        for (l, plan) in self.plans.iter().enumerate().rev() {
            let pi = 2 * l;
            let (x, out) = (&acts[l], &acts[l + 1]);
            match plan {
                Plan::Conv { cout, k, p, gather, celu, in_len, out_len } => {
                    if *celu {
                        for (d, a) in delta.iter_mut().zip(out.iter()) {
                            *d *= celu_grad_from_act(*a);
                        }
                    }
                    let w = &state.arrays[pi];
                    let mut dx = vec![0.0f32; b * in_len];
                    patch.clear();
                    patch.resize(p * k, 0.0);
                    dpatch.clear();
                    dpatch.resize(p * k, 0.0);
                    for s in 0..b {
                        let sample = &x[s * in_len..(s + 1) * in_len];
                        let d_out = &delta[s * out_len..(s + 1) * out_len];
                        // Bias gradient: sum over spatial positions.
                        for (co, db) in grads[pi + 1].iter_mut().enumerate() {
                            let row = &d_out[co * p..(co + 1) * p];
                            *db += row.iter().sum::<f32>();
                        }
                        // Weight gradient: dW (cout, k) += dOut (cout, p) · patch (p, k).
                        for (dst, &src) in patch.iter_mut().zip(gather.iter()) {
                            *dst = sample[src as usize];
                        }
                        matmul_nn_acc(d_out, &patch, *cout, *k, *p, &mut grads[pi]);
                        // Patch gradient: dPatch (p, k) = dOutᵀ (p, cout) · w (cout, k),
                        // scatter-added back through the gather table.
                        dpatch.iter_mut().for_each(|v| *v = 0.0);
                        matmul_tn_acc(d_out, w, *cout, *k, *p, &mut dpatch);
                        let dxs = &mut dx[s * in_len..(s + 1) * in_len];
                        for (&src, &dv) in gather.iter().zip(dpatch.iter()) {
                            dxs[src as usize] += dv;
                        }
                    }
                    delta = dx;
                }
                Plan::Dense { k, n, celu } => {
                    if *celu {
                        for (d, a) in delta.iter_mut().zip(out.iter()) {
                            *d *= celu_grad_from_act(*a);
                        }
                    }
                    let w = &state.arrays[pi];
                    // Bias gradient: column sums of delta (b, n).
                    for row in delta.chunks_exact(*n) {
                        for (db, dv) in grads[pi + 1].iter_mut().zip(row) {
                            *db += *dv;
                        }
                    }
                    // Weight gradient: dW (k, n) += xᵀ (k, b) · delta (b, n).
                    matmul_tn_acc(x, &delta, b, *n, *k, &mut grads[pi]);
                    // Input gradient: dx (b, k) = delta (b, n) · wᵀ; w (k, n)
                    // row-major is exactly matmul_nt's packed (k, n) operand.
                    let mut dx = vec![0.0f32; b * k];
                    matmul_nt(&delta, w, b, *k, *n, &mut dx);
                    delta = dx;
                }
            }
        }
        Ok((loss, grads))
    }

    /// One SGD minibatch step (`w -= lr * dL/dw`); returns the batch loss.
    pub fn step(&self, state: &mut ModelState, xb: &[f32], yb: &[f32], lr: f32) -> Result<f64> {
        let (loss, grads) = self.loss_and_grads(state, xb, yb)?;
        for (arr, grad) in state.arrays.iter_mut().zip(&grads) {
            for (wv, gv) in arr.iter_mut().zip(grad) {
                *wv -= lr * gv;
            }
        }
        Ok(loss)
    }
}

impl Trainer for NativeTrainer {
    fn backend(&self) -> BackendKind {
        BackendKind::Native
    }

    fn train(
        &self,
        cfg: &TrainConfig,
        train_ds: &Dataset,
        test_ds: &Dataset,
        progress: &mut dyn FnMut(&EpochLog),
    ) -> Result<(ModelState, TrainReport)> {
        anyhow::ensure!(cfg.batch >= 1, "TrainConfig.batch must be >= 1");
        // The PJRT trainer selects its artifact by cfg.variant; hold the
        // native side to the same contract so a mismatched config cannot
        // silently train a different architecture.
        anyhow::ensure!(
            cfg.variant == self.arch.name,
            "TrainConfig names variant '{}' but this trainer was built for '{}'",
            cfg.variant,
            self.arch.name
        );
        anyhow::ensure!(
            train_ds.d == self.meta.n_features(),
            "dataset features {} vs arch {}",
            train_ds.d,
            self.meta.n_features()
        );
        anyhow::ensure!(
            train_ds.o == self.meta.outputs,
            "dataset outputs {} vs arch {}",
            train_ds.o,
            self.meta.outputs
        );
        anyhow::ensure!(train_ds.n > 0, "empty training set");

        let mut state = ModelState::init(&self.meta, cfg.seed);
        let mut rng = Rng::seed_from(cfg.seed ^ 0x5EED);
        let batch = cfg.batch.min(train_ds.n);
        let steps_per_epoch = train_ds.n.div_ceil(batch);
        let mut xb: Vec<f32> = Vec::new();
        let mut yb: Vec<f32> = Vec::new();
        let mut history = Vec::with_capacity(cfg.epochs);
        let mut final_train_loss = f64::NAN;
        let t0 = Instant::now();
        let mut total_steps = 0usize;

        for epoch in 0..cfg.epochs {
            let mut sp = crate::obs::span("train.epoch");
            sp.counter("epoch", epoch as u64);
            let lr = cfg.lr.at(epoch);
            let order = rng.permutation(train_ds.n);
            let mut loss_acc = 0.0f64;
            for idx in order.chunks(batch) {
                // Native execution takes exact batch sizes — no padding.
                xb.clear();
                yb.clear();
                for &i in idx {
                    xb.extend_from_slice(train_ds.features(i));
                    yb.extend_from_slice(train_ds.targets(i));
                }
                loss_acc += self.step(&mut state, &xb, &yb, lr as f32)?;
                total_steps += 1;
            }
            let train_loss = loss_acc / steps_per_epoch as f64;
            final_train_loss = train_loss;
            let test_loss = if (cfg.eval_every > 0 && (epoch + 1) % cfg.eval_every == 0)
                || epoch + 1 == cfg.epochs
            {
                Some(evaluate_native(&self.meta, &state, test_ds)?.mse)
            } else {
                None
            };
            let row = EpochLog { epoch, lr, train_loss, test_loss };
            progress(&row);
            history.push(row);
        }

        let test = evaluate_native(&self.meta, &state, test_ds)?;
        if let Some(path) = &cfg.ckpt_out {
            state.save(path)?;
        }
        Ok((
            state,
            TrainReport {
                history,
                final_train_loss,
                test,
                wall_seconds: t0.elapsed().as_secs_f64(),
                steps: total_steps,
            },
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::NativeEngine;

    /// A tiny stack exercising every layer kind (conv ± CELU, flatten,
    /// dense ± CELU) — small enough for exhaustive finite differences.
    fn tiny_arch() -> Arch {
        let arch = Arch {
            name: "tiny".into(),
            input: [2, 1, 2, 2],
            outputs: 1,
            layers: vec![
                Layer::Conv { cin: 2, cout: 3, k: [1, 2, 1], s: [1, 2, 1], celu: true },
                Layer::Conv { cin: 3, cout: 2, k: [1, 1, 2], s: [1, 1, 1], celu: false },
                Layer::Flatten,
                Layer::Dense { cin: 2, cout: 4, celu: true },
                Layer::Dense { cin: 4, cout: 1, celu: false },
            ],
        };
        arch.validate().unwrap();
        arch
    }

    #[test]
    fn forward_tape_matches_engine() {
        for name in ["small", "cfg_a", "cfg_b"] {
            let arch = Arch::for_variant(name).unwrap();
            let state = ModelState::init(&arch.to_meta(), 3);
            let trainer = NativeTrainer::new(arch.clone()).unwrap();
            let engine = NativeEngine::new(&arch, &state).unwrap();
            let mut rng = Rng::seed_from(17);
            let x: Vec<f32> =
                (0..2 * arch.n_features()).map(|_| rng.range(-0.2, 1.2) as f32).collect();
            let tape = trainer.forward_tape(&state, &x).unwrap();
            let want = engine.forward(&x).unwrap();
            let got = tape.last().unwrap();
            assert_eq!(got.len(), want.len(), "{name}");
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() <= 1e-5, "{name}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn sgd_reduces_loss_on_a_fixed_batch() {
        let trainer = NativeTrainer::new(tiny_arch()).unwrap();
        let meta = trainer.meta().clone();
        let mut state = ModelState::init(&meta, 5);
        let mut rng = Rng::seed_from(6);
        let b = 8;
        let xb: Vec<f32> =
            (0..b * meta.n_features()).map(|_| rng.range(0.0, 1.0) as f32).collect();
        let yb: Vec<f32> = (0..b * meta.outputs).map(|_| rng.range(-0.1, 0.1) as f32).collect();
        let l0 = trainer.loss(&state, &xb, &yb).unwrap();
        for _ in 0..200 {
            trainer.step(&mut state, &xb, &yb, 0.02).unwrap();
        }
        let l1 = trainer.loss(&state, &xb, &yb).unwrap();
        assert!(l1.is_finite() && l1 < l0 * 0.5, "loss did not drop: {l0} -> {l1}");
    }

    #[test]
    fn output_weights_scale_loss_and_gradients_consistently() {
        // A two-head arch: weighting head 1 by zero must make its error
        // invisible to both the loss and every gradient (checked against
        // finite differences of the weighted loss itself).
        let arch = Arch {
            name: "two_head".into(),
            input: [1, 1, 1, 3],
            outputs: 2,
            layers: vec![Layer::Flatten, Layer::Dense { cin: 3, cout: 2, celu: false }],
        };
        let mut trainer = NativeTrainer::new(arch).unwrap();
        let state = ModelState::init(trainer.meta(), 11);
        let xb = [0.3f32, -0.2, 0.9, 0.1, 0.7, -0.4];
        let yb = [0.5f32, 100.0, -0.25, -100.0]; // wild head-1 targets
        assert!(trainer.set_output_weights(vec![1.0]).is_err()); // wrong len
        assert!(trainer.set_output_weights(vec![1.0, -1.0]).is_err());
        trainer.set_output_weights(vec![1.0, 0.0]).unwrap();
        let (loss, grads) = trainer.loss_and_grads(&state, &xb, &yb).unwrap();
        // Zero-weighted head: loss only sees column 0.
        let mut want = 0.0f64;
        let engine = NativeEngine::new(trainer.arch(), &state).unwrap();
        let preds = engine.forward(&xb).unwrap();
        for i in 0..2 {
            let e = (preds[i * 2] - yb[i * 2]) as f64;
            want += e * e;
        }
        assert!((loss - want / 4.0).abs() < 1e-9, "loss {loss} vs {want}");
        // Gradients match finite differences of the weighted loss.
        let eps = 1e-3f32;
        for (ai, arr) in state.arrays.iter().enumerate() {
            for k in 0..arr.len() {
                let mut plus = state.clone();
                plus.arrays[ai][k] += eps;
                let mut minus = state.clone();
                minus.arrays[ai][k] -= eps;
                let fd = (trainer.loss(&plus, &xb, &yb).unwrap()
                    - trainer.loss(&minus, &xb, &yb).unwrap())
                    / (2.0 * eps as f64);
                let an = grads[ai][k] as f64;
                assert!(
                    (fd - an).abs() <= 1e-3 * (1.0 + fd.abs().max(an.abs())),
                    "array {ai}[{k}]: fd {fd} vs analytic {an}"
                );
            }
        }
    }

    #[test]
    fn inf_in_gradient_stream_poisons_grads_not_vanishes() {
        // Regression for the kernels' old `av == 0.0` accumulate skip: a
        // diverged target makes delta = -inf, and the weight gradient
        // dW = xᵀ·delta must go NaN (0·∞) on rows fed by a zero feature —
        // not stay at a clean-looking 0.0 that masks the divergence.
        let arch = Arch {
            name: "one_dense".into(),
            input: [1, 1, 1, 2],
            outputs: 1,
            layers: vec![Layer::Flatten, Layer::Dense { cin: 2, cout: 1, celu: false }],
        };
        let trainer = NativeTrainer::new(arch).unwrap();
        let state = ModelState::init(trainer.meta(), 2);
        let xb = [0.0f32, 1.0]; // feature 0 is exactly zero
        let yb = [f32::INFINITY];
        for forced in [false, true] {
            let _g = forced.then(crate::infer::kernels::force_scalar);
            let (loss, grads) = trainer.loss_and_grads(&state, &xb, &yb).unwrap();
            assert!(loss.is_infinite(), "diverged loss must surface: {loss}");
            // dW[0] = 0.0 · (-inf) = NaN; dW[1] = 1.0 · (-inf) = -inf.
            assert!(grads[0][0].is_nan(), "forced={forced}: zero-feature grad {}", grads[0][0]);
            assert!(grads[0][1].is_infinite(), "forced={forced}: grad {}", grads[0][1]);
            assert!(grads[1][0].is_infinite(), "forced={forced}: bias grad {}", grads[1][0]);
        }
    }

    #[test]
    fn rejects_mismatched_shapes() {
        let trainer = NativeTrainer::new(tiny_arch()).unwrap();
        let meta = trainer.meta().clone();
        let state = ModelState::init(&meta, 0);
        let nf = meta.n_features();
        assert!(trainer.loss(&state, &vec![0.0; nf + 1], &[0.0]).is_err());
        assert!(trainer.loss(&state, &vec![0.0; nf], &[0.0, 0.0]).is_err());
        let other = ModelState::init(&Arch::for_variant("small").unwrap().to_meta(), 0);
        assert!(trainer.loss(&other, &vec![0.0; nf], &[0.0]).is_err());
    }

    #[test]
    fn trainer_trait_runs_end_to_end() {
        let trainer = NativeTrainer::new(tiny_arch()).unwrap();
        let meta = trainer.meta().clone();
        let (n, d, o) = (24usize, meta.n_features(), meta.outputs);
        let mut rng = Rng::seed_from(9);
        let x: Vec<f32> = (0..n * d).map(|_| rng.range(0.0, 1.0) as f32).collect();
        // A learnable target: mean of the features, scaled down.
        let y: Vec<f32> = (0..n)
            .map(|i| x[i * d..(i + 1) * d].iter().sum::<f32>() / d as f32 * 0.1)
            .collect();
        let ds = Dataset::new(n, d, o, x, y);
        let mut cfg = TrainConfig::new("tiny", 30);
        cfg.lr = crate::coordinator::LrSchedule::paper_scaled(0.02, 30);
        cfg.batch = 8;
        cfg.eval_every = 10;
        let mut rows = 0usize;
        let (state, report) =
            Trainer::train(&trainer, &cfg, &ds, &ds, &mut |_row| rows += 1).unwrap();
        assert_eq!(rows, 30);
        assert_eq!(report.history.len(), 30);
        assert_eq!(report.steps, 30 * 3);
        assert!(report.final_train_loss < report.history[0].train_loss, "{report:?}");
        // The returned state matches what the engine would serve.
        let engine = NativeEngine::new(trainer.arch(), &state).unwrap();
        assert_eq!(engine.n_outputs(), o);
    }
}
