//! Naive forward pass: the correctness oracle for the packed engine.
//!
//! Direct loop-nest convolutions and dense layers over `(C, D, H, W)`
//! row-major tensors, mirroring `python/compile/kernels/ref.py` (VALID
//! padding, CELU alpha = 1). Deliberately unoptimized and allocation-happy;
//! the parity proptests hold [`crate::infer::NativeEngine`] to this within
//! float tolerance, and the per-output accumulation order matches the
//! packed kernels so agreement is tight.

use anyhow::Result;

use crate::model::ModelState;

use super::arch::{Arch, Layer};
use super::kernels::celu;

/// Forward `x` (`batch * n_features`, batch-major) through `arch` with the
/// parameters in `state`; returns `batch * outputs` predictions.
pub fn forward(arch: &Arch, state: &ModelState, x: &[f32]) -> Result<Vec<f32>> {
    let nf = arch.n_features();
    anyhow::ensure!(nf > 0 && x.len() % nf == 0, "input is not whole samples of {nf} features");
    let batch = x.len() / nf;
    let specs = arch.param_specs();
    anyhow::ensure!(
        specs.len() == state.arrays.len(),
        "state has {} arrays, arch wants {}",
        state.arrays.len(),
        specs.len()
    );
    for (spec, arr) in specs.iter().zip(&state.arrays) {
        anyhow::ensure!(spec.numel() == arr.len(), "array '{}' size mismatch", spec.name);
    }

    let mut out = Vec::with_capacity(batch * arch.outputs);
    for s in 0..batch {
        let y = forward_one(arch, state, &x[s * nf..(s + 1) * nf])?;
        out.extend_from_slice(&y);
    }
    Ok(out)
}

fn forward_one(arch: &Arch, state: &ModelState, x: &[f32]) -> Result<Vec<f32>> {
    let mut c = arch.input[0];
    let mut dims = [arch.input[1], arch.input[2], arch.input[3]];
    let mut cur = x.to_vec();
    let mut p = 0usize; // parameter-array cursor
    for ly in &arch.layers {
        match ly {
            Layer::Conv { cin, cout, k, s, celu: act } => {
                let (w, b) = (&state.arrays[p], &state.arrays[p + 1]);
                p += 2;
                let [d_in, h_in, w_in] = dims;
                let od = (d_in - k[0]) / s[0] + 1;
                let oh = (h_in - k[1]) / s[1] + 1;
                let ow = (w_in - k[2]) / s[2] + 1;
                let mut next = vec![0.0f32; cout * od * oh * ow];
                for co in 0..*cout {
                    for zd in 0..od {
                        for zh in 0..oh {
                            for zw in 0..ow {
                                let mut acc = 0.0f32;
                                for ci in 0..*cin {
                                    for kd in 0..k[0] {
                                        for kh in 0..k[1] {
                                            for kw in 0..k[2] {
                                                let wi = ((((co * cin + ci) * k[0] + kd) * k[1]
                                                    + kh)
                                                    * k[2])
                                                    + kw;
                                                let xi = ((ci * d_in + zd * s[0] + kd) * h_in
                                                    + zh * s[1]
                                                    + kh)
                                                    * w_in
                                                    + zw * s[2]
                                                    + kw;
                                                acc += w[wi] * cur[xi];
                                            }
                                        }
                                    }
                                }
                                let z = acc + b[co];
                                next[((co * od + zd) * oh + zh) * ow + zw] =
                                    if *act { celu(z) } else { z };
                            }
                        }
                    }
                }
                cur = next;
                c = *cout;
                dims = [od, oh, ow];
            }
            Layer::Flatten => {
                // (C, D, H, W) row-major is already the flat layout.
                c *= dims[0] * dims[1] * dims[2];
                dims = [1, 1, 1];
            }
            Layer::Dense { cin, cout, celu: act } => {
                let (w, b) = (&state.arrays[p], &state.arrays[p + 1]);
                p += 2;
                anyhow::ensure!(cur.len() == *cin, "dense input width");
                let mut next = vec![0.0f32; *cout];
                for (n, nx) in next.iter_mut().enumerate() {
                    let mut acc = 0.0f32;
                    for (kk, cv) in cur.iter().enumerate() {
                        acc += cv * w[kk * cout + n];
                    }
                    let z = acc + b[n];
                    *nx = if *act { celu(z) } else { z };
                }
                cur = next;
                c = *cout;
            }
        }
    }
    anyhow::ensure!(c == arch.outputs && cur.len() == arch.outputs, "output width");
    Ok(cur)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_runs_all_builtin_variants() {
        for name in ["small", "cfg_a", "cfg_b"] {
            let arch = Arch::for_variant(name).unwrap();
            let meta = arch.to_meta();
            let state = ModelState::init(&meta, 42);
            let x = vec![0.3f32; 2 * arch.n_features()];
            let y = forward(&arch, &state, &x).unwrap();
            assert_eq!(y.len(), 2 * arch.outputs, "{name}");
            assert!(y.iter().all(|v| v.is_finite()), "{name}");
            // Identical rows produce identical outputs.
            assert_eq!(y[..arch.outputs], y[arch.outputs..], "{name}");
        }
    }

    #[test]
    fn rejects_ragged_input() {
        let arch = Arch::for_variant("small").unwrap();
        let state = ModelState::init(&arch.to_meta(), 0);
        assert!(forward(&arch, &state, &vec![0.0f32; 7]).is_err());
    }
}
