"""L2: the SEMULATOR regression network — forward, loss, Adam train step.

Pure functions over flat parameter lists (ordered per
:func:`compile.arch.param_specs`), so the whole training step AOT-lowers to
a single HLO computation the rust coordinator can execute via PJRT with
donated buffers. The Conv4Xbar layers dispatch to the Pallas patch-matmul
kernel (:mod:`compile.kernels`), so the kernel is on the compute path of
every artifact, forward and training alike.
"""

import jax
import jax.numpy as jnp

from .arch import CELU_ALPHA, param_specs
from .kernels import conv4xbar, fused_linear

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


def init_params(arch, key):
    """Kaiming-uniform initialization; returns the flat parameter list."""
    params = []
    for spec in param_specs(arch):
        key, sub = jax.random.split(key)
        params.append(
            jax.random.uniform(sub, spec["shape"], jnp.float32, -spec["bound"], spec["bound"])
        )
    return params


def forward(arch, params, x):
    """x: (B, C, D, H, W) normalized features -> (B, outputs) volts."""
    b = x.shape[0]
    it = iter(params)
    h = x
    for ly in arch["layers"]:
        if ly["type"] == "conv":
            w, bias = next(it), next(it)
            h = conv4xbar(h, w, bias, ly["s"], ly["celu"], CELU_ALPHA)
        elif ly["type"] == "flatten":
            h = h.reshape(b, -1)
        elif ly["type"] == "dense":
            w, bias = next(it), next(it)
            h = fused_linear(h, w, bias, ly["celu"], CELU_ALPHA)
    return h


def forward_ref(arch, params, x):
    """Reference forward pass on stock XLA ops (no Pallas) — identical math.

    Used for the kernel-ablation artifact (`fwd_*_ref`): comparing its PJRT
    cost against the Pallas-path artifact isolates the interpret-mode
    lowering overhead (EXPERIMENTS.md §Perf).
    """
    from .kernels import ref

    b = x.shape[0]
    it = iter(params)
    h = x
    for ly in arch["layers"]:
        if ly["type"] == "conv":
            w, bias = next(it), next(it)
            h = ref.conv3d_ref(h, w, bias, ly["s"], ly["celu"], CELU_ALPHA)
        elif ly["type"] == "flatten":
            h = h.reshape(b, -1)
        elif ly["type"] == "dense":
            w, bias = next(it), next(it)
            h = ref.linear_ref(h, w, bias, ly["celu"], CELU_ALPHA)
    return h


def mse_loss(arch, params, x, y):
    """Mean squared error over batch and outputs (paper's training loss)."""
    pred = forward(arch, params, x)
    return jnp.mean((pred - y) ** 2)


def eval_errors(arch, params, x, y):
    """Per-sample error tensors for MAE / Thm 4.1 / Fig 7: (abs, sq), each
    (B, outputs)."""
    pred = forward(arch, params, x)
    err = pred - y
    return jnp.abs(err), err**2


def init_opt_state(params):
    """Adam state: (m, v, step)."""
    zeros = [jnp.zeros_like(p) for p in params]
    return zeros, [jnp.zeros_like(p) for p in params], jnp.zeros((), jnp.float32)


def train_step(arch, params, m, v, step, x, y, lr):
    """One Adam step at learning rate `lr` (a traced scalar, so the rust
    side owns the schedule — paper Fig 4 halves it at fixed epochs).

    Returns (new_params, new_m, new_v, new_step, loss).
    """
    loss, grads = jax.value_and_grad(lambda p: mse_loss(arch, p, x, y))(params)
    step = step + 1.0
    bc1 = 1.0 - ADAM_B1**step
    bc2 = 1.0 - ADAM_B2**step
    new_params, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = ADAM_B1 * mi + (1.0 - ADAM_B1) * g
        vi = ADAM_B2 * vi + (1.0 - ADAM_B2) * g * g
        p = p - lr * (mi / bc1) / (jnp.sqrt(vi / bc2) + ADAM_EPS)
        new_params.append(p)
        new_m.append(mi)
        new_v.append(vi)
    return new_params, new_m, new_v, step, loss
