"""SEMULATOR network architectures (paper Table 2) as declarative specs.

Each arch is a dict:
    input   — (C, D, H, W) cell-feature tensor shape (no batch dim)
    outputs — number of MAC output voltages
    layers  — list of layer specs:
        {"type": "conv",  "cin", "cout", "k": (kD,kH,kW), "s": (sD,sH,sW), "celu": bool}
        {"type": "flatten"}
        {"type": "dense", "cin", "cout", "celu": bool}

Note on cfg_b: the paper lists stride (1,1,1) for the last conv of both
variants, but its own Linear(256, 32) only type-checks on the (2,2,64,8)
input if that layer has stride (1,1,2) (32ch * D2 * H1 * W4 = 256). We use
stride (1,1,2) there and record the discrepancy in DESIGN.md.
"""

from .kernels import conv4xbar_out_shape

CELU_ALPHA = 1.0


def _conv(cin, cout, k, s, celu=True):
    return {"type": "conv", "cin": cin, "cout": cout, "k": tuple(k), "s": tuple(s), "celu": celu}


def _dense(cin, cout, celu=True):
    return {"type": "dense", "cin": cin, "cout": cout, "celu": celu}


def _xbar_stack(head_h_kernels, last_w_kernel, last_w_stride):
    """The shared Conv4Xbar trunk of Table 2: per-cell 1x1x1 features, then
    column-wise (H) reductions, then the cross-column (W) mix."""
    layers = [_conv(2, 16, (1, 1, 1), (1, 1, 1))]
    cin = 16
    for cout, kh in head_h_kernels:
        layers.append(_conv(cin, cout, (1, kh, 1), (1, kh, 1)))
        cin = cout
    layers.append(_conv(cin, 32, (1, 1, last_w_kernel), (1, 1, last_w_stride)))
    return layers


ARCHS = {
    # Table 1 row 1 / Table 2 row 1: (2,4,64,2) -> 1 voltage.
    "cfg_a": {
        "input": (2, 4, 64, 2),
        "outputs": 1,
        "layers": _xbar_stack([(8, 2), (4, 4), (32, 8)], 2, 1)
        + [{"type": "flatten"}, _dense(128, 32), _dense(32, 16), _dense(16, 1, celu=False)],
    },
    # Table 1 row 2 / Table 2 row 2: (2,2,64,8) -> 4 voltages.
    "cfg_b": {
        "input": (2, 2, 64, 8),
        "outputs": 4,
        "layers": _xbar_stack([(8, 2), (4, 4), (32, 8)], 2, 2)
        + [{"type": "flatten"}, _dense(256, 32), _dense(32, 16), _dense(16, 4, celu=False)],
    },
    # Reduced block for single-core end-to-end runs: (2,2,16,2) -> 1 voltage.
    "small": {
        "input": (2, 2, 16, 2),
        "outputs": 1,
        "layers": _xbar_stack([(8, 2), (32, 8)], 2, 1)
        + [{"type": "flatten"}, _dense(64, 32), _dense(32, 16), _dense(16, 1, celu=False)],
    },
}


def validate_arch(arch):
    """Shape-check the layer stack; returns the flattened feature count."""
    c, d, h, w = arch["input"]
    spatial = (d, h, w)
    flat = None
    for ly in arch["layers"]:
        if ly["type"] == "conv":
            assert ly["cin"] == c, f"conv cin {ly['cin']} != {c}"
            spatial = conv4xbar_out_shape(spatial, ly["cout"], ly["k"], ly["s"])
            c = ly["cout"]
        elif ly["type"] == "flatten":
            flat = c * spatial[0] * spatial[1] * spatial[2]
            c = flat
        elif ly["type"] == "dense":
            assert ly["cin"] == c, f"dense cin {ly['cin']} != {c}"
            c = ly["cout"]
        else:
            raise ValueError(f"unknown layer {ly['type']}")
    assert c == arch["outputs"], f"final width {c} != outputs {arch['outputs']}"
    return flat


def param_specs(arch):
    """Ordered parameter descriptors: name, shape, init bound (Kaiming-
    uniform, like torch's Conv3d/Linear defaults)."""
    specs = []
    for i, ly in enumerate(arch["layers"]):
        if ly["type"] == "conv":
            kd, kh, kw = ly["k"]
            fan_in = ly["cin"] * kd * kh * kw
            bound = (1.0 / fan_in) ** 0.5
            specs.append({"name": f"conv{i}.w", "shape": (ly["cout"], ly["cin"], kd, kh, kw), "bound": bound})
            specs.append({"name": f"conv{i}.b", "shape": (ly["cout"],), "bound": bound})
        elif ly["type"] == "dense":
            bound = (1.0 / ly["cin"]) ** 0.5
            specs.append({"name": f"dense{i}.w", "shape": (ly["cin"], ly["cout"]), "bound": bound})
            specs.append({"name": f"dense{i}.b", "shape": (ly["cout"],), "bound": bound})
    return specs


def n_parameters(arch):
    total = 0
    for s in param_specs(arch):
        n = 1
        for dim in s["shape"]:
            n *= dim
        total += n
    return total
