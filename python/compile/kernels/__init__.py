"""L1 Pallas kernels for SEMULATOR (build-time only, interpret=True).

`fused_linear` is the single compute hot-spot: matmul + bias + CELU fused
for the MXU. `conv4xbar` lowers every Conv4Xbar layer onto it via disjoint
patch extraction. `ref` holds the pure-jnp oracles used by pytest.
"""

from . import ref
from .conv4xbar import conv4xbar, conv4xbar_out_shape
from .fused_linear import fused_linear, fused_linear_pallas

__all__ = ["ref", "conv4xbar", "conv4xbar_out_shape", "fused_linear", "fused_linear_pallas"]
