"""Conv4Xbar layers as patch matmuls over the fused Pallas kernel.

The paper's feature extractor (Fig. 3 / Table 2) uses 3D convolutions whose
kernels have depth 1 and stride equal to kernel size — each layer partitions
the (tile, row, col) grid into disjoint patches and applies one shared
filter per patch, which is exactly how the crossbar shares one cell model
``d(.)`` across all cells. Here each such layer is lowered to

    reshape -> (B * D' * H' * W', Cin * kH * kW) @ (Cin * kH * kW, Cout)

and dispatched to :func:`..kernels.fused_linear.fused_linear` (MXU matmul +
fused bias/CELU). See DESIGN.md §Hardware-Adaptation.

Supported geometry per spatial dim: ``stride == kernel`` (disjoint patches),
or ``stride == 1`` with ``kernel == dim`` (a single patch — e.g. the final
(1,1,2) layer on W=2 in cfg_a). Anything else is not a Conv4Xbar layer.
"""

import jax.numpy as jnp

from .fused_linear import fused_linear


def _blocks(dim: int, k: int, s: int) -> int:
    """Number of output positions along one spatial dim."""
    if s == k:
        assert dim % k == 0, f"dim {dim} not divisible by kernel {k}"
        return dim // k
    if s == 1 and k == dim:
        return 1
    raise ValueError(f"unsupported conv geometry: dim={dim} k={k} s={s}")


def conv4xbar(x, w, b, stride, apply_celu: bool, alpha: float = 1.0):
    """Conv4Xbar layer. x: (B, Cin, D, H, W), w: (Cout, Cin, kD, kH, kW).

    Returns (B, Cout, D', H', W').
    """
    bsz, cin, d, h, wd = x.shape
    cout, cin2, kd, kh, kw = w.shape
    sd, sh, sw = stride
    assert cin == cin2, f"channel mismatch {cin} vs {cin2}"
    assert kd == 1 and sd == 1, "Conv4Xbar kernels have unit depth"
    od, oh, ow = d, _blocks(h, kh, sh), _blocks(wd, kw, sw)

    # Patch extraction by pure reshape/transpose (no data duplication —
    # patches are disjoint). (B, C, D, H, W) -> (B, C, D, oh, kh, ow, kw).
    xp = x.reshape(bsz, cin, d, oh, kh, ow, kw)
    # -> (B, D, oh, ow, C, kh, kw): positions major, patch content minor.
    xp = xp.transpose(0, 2, 3, 5, 1, 4, 6)
    a = xp.reshape(bsz * od * oh * ow, cin * kh * kw)

    # Weights: (Cout, Cin, 1, kh, kw) -> (Cin * kh * kw, Cout), matching the
    # patch content order (C, kh, kw).
    wm = w.reshape(cout, cin * kh * kw).T

    y = fused_linear(a, wm, b, apply_celu, alpha)

    # (B * D' * H' * W', Cout) -> (B, Cout, D', H', W').
    y = y.reshape(bsz, od, oh, ow, cout).transpose(0, 4, 1, 2, 3)
    return y


def conv4xbar_out_shape(in_shape, cout, kernel, stride):
    """Static output spatial shape for architecture checking."""
    d, h, w = in_shape
    _, kh, kw = kernel
    _, sh, sw = stride
    return (d, _blocks(h, kh, sh), _blocks(w, kw, sw))
