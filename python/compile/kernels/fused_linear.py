"""Fused matmul + bias + CELU as a Pallas kernel — the L1 hot-spot.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): every Conv4Xbar layer
has unit-depth kernels with stride == kernel size, i.e. it partitions its
input into disjoint patches. On TPU that is not a sliding-window convolution
at all — it is a patch matrix times a small weight matrix, which feeds the
MXU directly. This kernel is that matmul with the bias add and CELU fused in
(VPU elementwise after the MXU pass), so one layer = one VMEM round trip.

The grid tiles the M (batch*positions) dimension; the full K x N weight tile
stays resident in VMEM across the grid (K*N here is at most a few thousand
floats — far under the ~16 MiB VMEM budget; see DESIGN.md §Perf for the
accounting).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel lowers through the interpreter into plain HLO.
Numerics are identical; TPU performance is estimated statically.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# M-dimension tile for a real TPU: 128 matches the MXU systolic dimension
# (see DESIGN.md §Perf for the VMEM/BlockSpec accounting at this size).
TPU_BLOCK_M = 128

# The artifacts in this repo target the CPU PJRT client, where the Pallas
# interpreter serializes the grid — a 1024-step grid of tiny MXU tiles is
# ~50x slower than one fused dot. For the CPU schedule we therefore use a
# single full-M block (grid of 1). Tests exercise multi-block grids
# explicitly via the `block_m` argument; numerics are identical.
DEFAULT_BLOCK_M = None  # None -> full M in one block


def _kernel(a_ref, w_ref, b_ref, o_ref, *, apply_celu: bool, alpha: float):
    """One grid step: (bm, K) @ (K, N) + b, optional CELU."""
    a = a_ref[...]
    w = w_ref[...]
    z = jnp.dot(a, w, preferred_element_type=jnp.float32) + b_ref[...]
    if apply_celu:
        z = jnp.maximum(z, 0.0) + jnp.minimum(0.0, alpha * jnp.expm1(z / alpha))
    o_ref[...] = z


def fused_linear_pallas(a, w, b, apply_celu: bool, alpha: float = 1.0, block_m: int | None = DEFAULT_BLOCK_M):
    """``celu(a @ w + b)`` via Pallas. a: (M, K), w: (K, N), b: (N,)."""
    m, k = a.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert b.shape == (n,), f"bias shape {b.shape} vs ({n},)"

    bm = m if block_m is None else min(block_m, m)
    m_pad = (bm - m % bm) % bm
    if m_pad:
        a = jnp.pad(a, ((0, m_pad), (0, 0)))
    grid = (a.shape[0] // bm,)

    out = pl.pallas_call(
        functools.partial(_kernel, apply_celu=apply_celu, alpha=alpha),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),  # stream M tiles
            pl.BlockSpec((k, n), lambda i: (0, 0)),   # weights resident
            pl.BlockSpec((n,), lambda i: (0,)),       # bias resident
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((a.shape[0], n), jnp.float32),
        interpret=True,
    )(a, w, b)
    return out[:m] if m_pad else out


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def fused_linear(a, w, b, apply_celu: bool, alpha: float = 1.0):
    """Differentiable fused linear layer.

    Forward runs the Pallas kernel; backward is closed-form jnp (Pallas
    interpret-mode has no transpose rule, and the backward pass is itself
    two matmuls XLA fuses well).
    """
    return fused_linear_pallas(a, w, b, apply_celu, alpha)


def _fwd(a, w, b, apply_celu, alpha):
    y = fused_linear_pallas(a, w, b, apply_celu, alpha)
    return y, (a, w, b)


def _bwd(apply_celu, alpha, res, gy):
    a, w, b = res
    if apply_celu:
        z = a @ w + b  # cheap recompute; saves storing pre-activations
        gz = gy * jnp.where(z >= 0.0, 1.0, jnp.exp(z / alpha))
    else:
        gz = gy
    ga = gz @ w.T
    gw = a.T @ gz
    gb = gz.sum(axis=0)
    return ga, gw, gb


fused_linear.defvjp(_fwd, _bwd)
