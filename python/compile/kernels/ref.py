"""Pure-jnp correctness oracles for the Pallas kernels.

Everything here is deliberately written with stock XLA ops
(``lax.conv_general_dilated`` for the Conv4Xbar layers, plain ``@`` for the
dense layers) so the Pallas implementations in this package have an
independent reference. pytest checks kernel-vs-ref allclose across a
hypothesis sweep of shapes — this is the CORE correctness signal for L1.
"""

import jax.numpy as jnp
from jax import lax


def celu(x, alpha: float = 1.0):
    """CELU activation (matches torch.nn.CELU)."""
    return jnp.maximum(x, 0.0) + jnp.minimum(0.0, alpha * jnp.expm1(x / alpha))


def celu_grad(x, alpha: float = 1.0):
    """d celu(x) / dx (used by the custom VJPs)."""
    return jnp.where(x >= 0.0, 1.0, jnp.exp(x / alpha))


def linear_ref(a, w, b, apply_celu: bool, alpha: float = 1.0):
    """Reference for the fused dense kernel: ``a @ w + b`` then CELU.

    a: (M, K), w: (K, N), b: (N,) -> (M, N)
    """
    z = a @ w + b
    return celu(z, alpha) if apply_celu else z


def conv3d_ref(x, w, b, stride, apply_celu: bool, alpha: float = 1.0):
    """Reference Conv4Xbar layer via XLA's general convolution.

    x: (B, Cin, D, H, W), w: (Cout, Cin, kD, kH, kW), b: (Cout,),
    stride: (sD, sH, sW), VALID padding -> (B, Cout, D', H', W').
    """
    z = lax.conv_general_dilated(
        x,
        w,
        window_strides=tuple(stride),
        padding="VALID",
        dimension_numbers=("NCDHW", "OIDHW", "NCDHW"),
    )
    z = z + b.reshape(1, -1, 1, 1, 1)
    return celu(z, alpha) if apply_celu else z
