"""AOT compile path: lower every SEMULATOR artifact to HLO text + meta.json.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax >=
0.5 emits protos with 64-bit instruction ids which the rust `xla` crate's
XLA (xla_extension 0.5.1) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Per variant (small / cfg_a / cfg_b) we emit:

    {name}_train.hlo.txt    (*params, *m, *v, step, x, y, lr) ->
                            (*params', *m', *v', step', loss)
    {name}_eval.hlo.txt     (*params, x, y) -> (abs_err, sq_err)   [B, O]
    {name}_fwd_b1.hlo.txt   (*params, x) -> (y,)                   latency path
    {name}_fwd_bN.hlo.txt   (*params, x) -> (y,)                   batch path

plus one shared `meta.json` describing shapes, parameter layout and init
bounds so the rust side never re-derives architecture facts.

Python runs ONCE at `make artifacts`; nothing here is on the request path.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import arch as A
from . import model as M

# Fixed batch sizes baked into the artifacts (PJRT executables have static
# shapes; the rust batcher pads to these).
TRAIN_BATCH = {"small": 128, "cfg_a": 256, "cfg_b": 256}
EVAL_BATCH = {"small": 256, "cfg_a": 256, "cfg_b": 256}
INFER_BATCHES = [1, 64]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def lower_variant(name, out_dir):
    """Lower all artifacts for one variant; returns its meta dict."""
    arch = A.ARCHS[name]
    A.validate_arch(arch)
    specs = A.param_specs(arch)
    p_specs = [f32(s["shape"]) for s in specs]
    n_p = len(p_specs)
    in_shape = arch["input"]
    n_out = arch["outputs"]

    artifacts = {}

    def emit(kind, fn, args):
        fname = f"{name}_{kind}.hlo.txt"
        text = to_hlo_text(jax.jit(fn).lower(*args))
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        return fname

    # --- train step -------------------------------------------------------
    bt = TRAIN_BATCH[name]

    def train_fn(*args):
        params = list(args[:n_p])
        m = list(args[n_p : 2 * n_p])
        v = list(args[2 * n_p : 3 * n_p])
        step, x, y, lr = args[3 * n_p :]
        new_p, new_m, new_v, new_step, loss = M.train_step(arch, params, m, v, step, x, y, lr)
        return tuple(new_p) + tuple(new_m) + tuple(new_v) + (new_step, loss)

    train_args = p_specs * 3 + [f32(()), f32((bt, *in_shape)), f32((bt, n_out)), f32(())]
    artifacts["train"] = {
        "file": emit("train", train_fn, train_args),
        "batch": bt,
        "n_inputs": 3 * n_p + 4,
        "n_outputs": 3 * n_p + 2,
    }

    # --- eval -------------------------------------------------------------
    be = EVAL_BATCH[name]

    def eval_fn(*args):
        params = list(args[:n_p])
        x, y = args[n_p :]
        return M.eval_errors(arch, params, x, y)

    eval_args = p_specs + [f32((be, *in_shape)), f32((be, n_out))]
    artifacts["eval"] = {
        "file": emit("eval", eval_fn, eval_args),
        "batch": be,
        "n_inputs": n_p + 2,
        "n_outputs": 2,
    }

    # --- forward (inference) ---------------------------------------------
    for bi in INFER_BATCHES:

        def fwd_fn(*args, _b=bi):
            params = list(args[:n_p])
            return (M.forward(arch, params, args[n_p]),)

        fwd_args = p_specs + [f32((bi, *in_shape))]
        artifacts[f"fwd_b{bi}"] = {
            "file": emit(f"fwd_b{bi}", fwd_fn, fwd_args),
            "batch": bi,
            "n_inputs": n_p + 1,
            "n_outputs": 1,
        }

    # --- kernel-ablation forward (stock-XLA ops, no Pallas) ---------------
    # Same math as fwd_b{max}; comparing PJRT cost isolates the Pallas
    # interpret-mode lowering overhead (EXPERIMENTS.md §Perf).
    bi = max(INFER_BATCHES)

    def fwd_ref_fn(*args):
        params = list(args[:n_p])
        return (M.forward_ref(arch, params, args[n_p]),)

    artifacts[f"fwd_b{bi}_ref"] = {
        "file": emit(f"fwd_b{bi}_ref", fwd_ref_fn, p_specs + [f32((bi, *in_shape))]),
        "batch": bi,
        "n_inputs": n_p + 1,
        "n_outputs": 1,
    }

    return {
        "input": list(in_shape),
        "outputs": n_out,
        "n_param_arrays": n_p,
        "n_parameters": A.n_parameters(arch),
        "params": [
            {"name": s["name"], "shape": list(s["shape"]), "bound": s["bound"]} for s in specs
        ],
        "artifacts": artifacts,
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--variants", nargs="*", default=list(A.ARCHS.keys()))
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    meta = {"version": 1, "infer_batches": INFER_BATCHES, "variants": {}}
    for name in args.variants:
        print(f"lowering {name} ...", flush=True)
        meta["variants"][name] = lower_variant(name, args.out_dir)

    meta_path = os.path.join(args.out_dir, "meta.json")
    # Merge with an existing meta when only a subset of variants was built.
    if os.path.exists(meta_path) and set(args.variants) != set(A.ARCHS.keys()):
        with open(meta_path) as f:
            old = json.load(f)
        old["variants"].update(meta["variants"])
        meta = old
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=2)
    print(f"wrote {meta_path}")


if __name__ == "__main__":
    main()
