import os
import sys

# Make `compile` importable regardless of pytest rootdir.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
