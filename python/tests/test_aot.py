"""AOT path: HLO text lowering and meta consistency.

Uses the `small` variant only (the paper configs take ~10s each to lower);
`make artifacts` exercises all of them.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import arch as A
from compile import model as M

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def small_artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    meta = aot.lower_variant("small", str(out))
    return out, meta


def test_emits_all_artifacts(small_artifacts):
    out, meta = small_artifacts
    for kind, art in meta["artifacts"].items():
        path = out / art["file"]
        assert path.exists(), kind
        text = path.read_text()
        assert text.startswith("HloModule"), f"{kind} is not HLO text"
        # The 0.5.1-compat check: text, not proto, and parameters present.
        assert "parameter(0)" in text


def test_meta_counts(small_artifacts):
    _, meta = small_artifacts
    n_p = meta["n_param_arrays"]
    assert n_p == len(meta["params"])
    assert meta["artifacts"]["train"]["n_inputs"] == 3 * n_p + 4
    assert meta["artifacts"]["train"]["n_outputs"] == 3 * n_p + 2
    assert meta["artifacts"]["eval"]["n_inputs"] == n_p + 2
    assert meta["artifacts"]["fwd_b1"]["batch"] == 1
    assert meta["n_parameters"] == A.n_parameters(A.ARCHS["small"])


def test_param_meta_matches_specs(small_artifacts):
    _, meta = small_artifacts
    specs = A.param_specs(A.ARCHS["small"])
    for ms, s in zip(meta["params"], specs):
        assert ms["name"] == s["name"]
        assert tuple(ms["shape"]) == tuple(s["shape"])
        assert abs(ms["bound"] - s["bound"]) < 1e-12


def test_hlo_text_has_no_64bit_ids(small_artifacts):
    """xla_extension 0.5.1 rejects instruction ids > INT_MAX; text re-parse
    reassigns them, but double-check none leak through the printer."""
    out, meta = small_artifacts
    import re

    text = (out / meta["artifacts"]["train"]["file"]).read_text()
    for tok in re.findall(r"id=(\d+)", text):
        assert int(tok) < 2**31


def test_lowered_fwd_executes_and_matches_model(small_artifacts):
    """Compile the lowered StableHLO back on the local CPU client and compare
    against a direct model call — guards the whole lower/serialize path."""
    arch = A.ARCHS["small"]
    params = M.init_params(arch, jax.random.PRNGKey(0))
    x = jax.random.uniform(jax.random.PRNGKey(1), (1, *arch["input"]), jnp.float32)

    fwd = jax.jit(lambda *args: (M.forward(arch, list(args[:-1]), args[-1]),))
    want = fwd(*params, x)[0]

    lowered = fwd.lower(*[jax.ShapeDtypeStruct(p.shape, p.dtype) for p in params],
                        jax.ShapeDtypeStruct(x.shape, x.dtype))
    compiled = lowered.compile()
    got = compiled(*params, x)[0]
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)


def test_repo_meta_json_is_valid_if_present():
    """If `make artifacts` has run, the checked-in meta must parse and cover
    every declared variant."""
    path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "meta.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    with open(path) as f:
        meta = json.load(f)
    assert meta["version"] == 1
    for name in meta["variants"]:
        assert name in A.ARCHS
        v = meta["variants"][name]
        assert v["input"] == list(A.ARCHS[name]["input"])
