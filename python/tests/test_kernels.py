"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes/values; the assertions are tight allclose checks —
this is the core correctness signal for the kernel layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv4xbar, fused_linear, fused_linear_pallas, ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------- fused_linear


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 300),
    k=st.integers(1, 64),
    n=st.integers(1, 48),
    celu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_linear_matches_ref(m, k, n, celu, seed):
    a = rand(seed, (m, k))
    w = rand(seed + 1, (k, n), 0.5)
    b = rand(seed + 2, (n,), 0.5)
    got = fused_linear_pallas(a, w, b, celu, 1.0)
    want = ref.linear_ref(a, w, b, celu, 1.0)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(block_m=st.sampled_from([1, 7, 32, 128]), m=st.integers(1, 200), seed=st.integers(0, 1000))
def test_fused_linear_grid_tiling_invariant(block_m, m, seed):
    """Multi-block grids (the TPU schedule) match the single-block result."""
    a = rand(seed, (m, 24))
    w = rand(seed + 1, (24, 8))
    b = rand(seed + 2, (8,))
    tiled = fused_linear_pallas(a, w, b, True, 1.0, block_m=block_m)
    full = fused_linear_pallas(a, w, b, True, 1.0, block_m=None)
    np.testing.assert_allclose(tiled, full, rtol=1e-6, atol=1e-6)


def test_fused_linear_alpha_variants():
    a = rand(0, (17, 9))
    w = rand(1, (9, 5))
    b = rand(2, (5,))
    for alpha in [0.5, 1.0, 2.0]:
        got = fused_linear_pallas(a, w, b, True, alpha)
        want = ref.linear_ref(a, w, b, True, alpha)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_fused_linear_gradients_match_ref():
    a = rand(3, (33, 12))
    w = rand(4, (12, 7))
    b = rand(5, (7,))

    def loss(f):
        return lambda aa, ww, bb: jnp.sum(f(aa, ww, bb, True, 1.0) ** 2)

    g = jax.grad(loss(fused_linear), argnums=(0, 1, 2))(a, w, b)
    gr = jax.grad(loss(ref.linear_ref), argnums=(0, 1, 2))(a, w, b)
    for gi, gri in zip(g, gr):
        np.testing.assert_allclose(gi, gri, rtol=1e-4, atol=1e-4)


def test_fused_linear_inside_jit():
    a = rand(6, (50, 10))
    w = rand(7, (10, 4))
    b = rand(8, (4,))
    f = jax.jit(lambda aa: fused_linear(aa, w, b, True, 1.0))
    np.testing.assert_allclose(f(a), ref.linear_ref(a, w, b, True, 1.0), rtol=1e-5, atol=1e-5)


def test_fused_linear_rejects_bad_shapes():
    with pytest.raises(AssertionError):
        fused_linear_pallas(rand(0, (4, 3)), rand(1, (5, 2)), rand(2, (2,)), False, 1.0)
    with pytest.raises(AssertionError):
        fused_linear_pallas(rand(0, (4, 3)), rand(1, (3, 2)), rand(2, (3,)), False, 1.0)


# ------------------------------------------------------------------ conv4xbar


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 8),
    cin=st.integers(1, 8),
    cout=st.integers(1, 16),
    d=st.integers(1, 4),
    h_blocks=st.integers(1, 8),
    kh=st.sampled_from([1, 2, 4]),
    w=st.sampled_from([1, 2, 4]),
    celu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv4xbar_stride_eq_kernel(b, cin, cout, d, h_blocks, kh, w, celu, seed):
    h = h_blocks * kh
    x = rand(seed, (b, cin, d, h, w))
    wt = rand(seed + 1, (cout, cin, 1, kh, 1), 0.4)
    bias = rand(seed + 2, (cout,), 0.2)
    got = conv4xbar(x, wt, bias, (1, kh, 1), celu)
    want = ref.conv3d_ref(x, wt, bias, (1, kh, 1), celu)
    assert got.shape == (b, cout, d, h // kh, w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_conv4xbar_single_patch_geometry():
    """The cfg_a final layer: kernel (1,1,2), stride (1,1,1) on W=2."""
    x = rand(0, (5, 32, 4, 1, 2))
    w = rand(1, (32, 32, 1, 1, 2), 0.2)
    b = rand(2, (32,), 0.2)
    got = conv4xbar(x, w, b, (1, 1, 1), True)
    want = ref.conv3d_ref(x, w, b, (1, 1, 1), True)
    assert got.shape == (5, 32, 4, 1, 1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_conv4xbar_w_stride_2():
    """The cfg_b final layer: kernel (1,1,2), stride (1,1,2) on W=8."""
    x = rand(3, (2, 32, 2, 1, 8))
    w = rand(4, (32, 32, 1, 1, 2), 0.2)
    b = rand(5, (32,), 0.2)
    got = conv4xbar(x, w, b, (1, 1, 2), True)
    want = ref.conv3d_ref(x, w, b, (1, 1, 2), True)
    assert got.shape == (2, 32, 2, 1, 4)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_conv4xbar_rejects_overlapping_windows():
    x = rand(0, (1, 2, 1, 8, 2))
    w = rand(1, (4, 2, 1, 3, 1))
    b = rand(2, (4,))
    with pytest.raises(ValueError):
        conv4xbar(x, w, b, (1, 1, 1), True)  # k=3, s=1, dim=8: overlapping


def test_conv4xbar_gradients_match_ref():
    x = rand(9, (4, 2, 2, 8, 2))
    w = rand(10, (6, 2, 1, 2, 1), 0.3)
    b = rand(11, (6,), 0.1)

    def mk(f):
        return lambda xx, ww, bb: jnp.sum(f(xx, ww, bb, (1, 2, 1), True) ** 2)

    g = jax.grad(mk(conv4xbar), argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(mk(ref.conv3d_ref), argnums=(0, 1, 2))(x, w, b)
    for gi, gri in zip(g, gr):
        np.testing.assert_allclose(gi, gri, rtol=1e-4, atol=1e-4)


def test_celu_matches_definition():
    x = jnp.linspace(-5, 5, 101)
    got = ref.celu(x, 1.3)
    want = jnp.where(x > 0, x, 1.3 * (jnp.exp(x / 1.3) - 1.0))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)
    # Gradient helper agrees with autodiff.
    g = jax.vmap(jax.grad(lambda v: ref.celu(v, 1.3)))(x)
    np.testing.assert_allclose(ref.celu_grad(x, 1.3), g, rtol=1e-6, atol=1e-7)
