"""L2 correctness: architecture shapes, training dynamics, eval outputs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import arch as A
from compile import model as M

jax.config.update("jax_platform_name", "cpu")


@pytest.mark.parametrize("name", list(A.ARCHS.keys()))
def test_arch_validates(name):
    A.validate_arch(A.ARCHS[name])


def test_table2_flatten_widths():
    """The flatten widths the paper's Table 2 dense layers expect."""
    assert A.validate_arch(A.ARCHS["cfg_a"]) == 128
    assert A.validate_arch(A.ARCHS["cfg_b"]) == 256
    assert A.validate_arch(A.ARCHS["small"]) == 64


@pytest.mark.parametrize("name", list(A.ARCHS.keys()))
def test_forward_shape(name):
    arch = A.ARCHS[name]
    params = M.init_params(arch, jax.random.PRNGKey(0))
    x = jnp.zeros((3, *arch["input"]), jnp.float32)
    y = M.forward(arch, params, x)
    assert y.shape == (3, arch["outputs"])
    assert jnp.all(jnp.isfinite(y))


def test_param_specs_order_matches_init():
    arch = A.ARCHS["small"]
    params = M.init_params(arch, jax.random.PRNGKey(1))
    specs = A.param_specs(arch)
    assert len(params) == len(specs)
    for p, s in zip(params, specs):
        assert p.shape == tuple(s["shape"]), s["name"]
        assert float(jnp.abs(p).max()) <= s["bound"] + 1e-7


def test_init_is_seed_deterministic():
    arch = A.ARCHS["small"]
    p1 = M.init_params(arch, jax.random.PRNGKey(7))
    p2 = M.init_params(arch, jax.random.PRNGKey(7))
    p3 = M.init_params(arch, jax.random.PRNGKey(8))
    for a, b in zip(p1, p2):
        np.testing.assert_array_equal(a, b)
    assert any(float(jnp.abs(a - b).max()) > 0 for a, b in zip(p1, p3))


def test_train_step_decreases_loss_on_fixed_batch():
    arch = A.ARCHS["small"]
    key = jax.random.PRNGKey(0)
    params = M.init_params(arch, key)
    m, v, step = M.init_opt_state(params)
    x = jax.random.uniform(jax.random.PRNGKey(1), (32, *arch["input"]), jnp.float32)
    y = jax.random.uniform(jax.random.PRNGKey(2), (32, arch["outputs"]), jnp.float32, -0.5, 0.5)
    ts = jax.jit(lambda p, mm, vv, ss, lr: M.train_step(arch, p, mm, vv, ss, x, y, lr))
    first = None
    loss = None
    for i in range(60):
        params, m, v, step, loss = ts(params, m, v, step, 3e-3)
        if first is None:
            first = float(loss)
    assert float(loss) < 0.3 * first, f"loss {first} -> {float(loss)}"
    assert float(step) == 60.0


def test_eval_errors_shapes_and_values():
    arch = A.ARCHS["small"]
    params = M.init_params(arch, jax.random.PRNGKey(0))
    x = jnp.zeros((5, *arch["input"]), jnp.float32)
    y = jnp.ones((5, arch["outputs"]), jnp.float32)
    abs_e, sq_e = M.eval_errors(arch, params, x, y)
    assert abs_e.shape == (5, arch["outputs"])
    assert sq_e.shape == (5, arch["outputs"])
    np.testing.assert_allclose(sq_e, abs_e**2, rtol=1e-5)
    # Identical rows -> identical errors.
    np.testing.assert_allclose(abs_e[0], abs_e[4], rtol=1e-6)


def test_mse_loss_zero_on_perfect_targets():
    arch = A.ARCHS["small"]
    params = M.init_params(arch, jax.random.PRNGKey(0))
    x = jax.random.uniform(jax.random.PRNGKey(3), (4, *arch["input"]), jnp.float32)
    y = M.forward(arch, params, x)
    assert float(M.mse_loss(arch, params, x, y)) < 1e-12


def test_parameter_count_small_vs_formula():
    arch = A.ARCHS["small"]
    params = M.init_params(arch, jax.random.PRNGKey(0))
    total = sum(int(np.prod(p.shape)) for p in params)
    assert total == A.n_parameters(arch)


def test_lr_zero_is_identity():
    arch = A.ARCHS["small"]
    params = M.init_params(arch, jax.random.PRNGKey(0))
    m, v, step = M.init_opt_state(params)
    x = jax.random.uniform(jax.random.PRNGKey(1), (8, *arch["input"]), jnp.float32)
    y = jnp.zeros((8, arch["outputs"]), jnp.float32)
    new_p, *_ = M.train_step(arch, params, m, v, step, x, y, 0.0)
    for a, b in zip(params, new_p):
        np.testing.assert_array_equal(a, b)


def test_forward_ref_matches_forward():
    """The no-Pallas ablation path must compute identical math."""
    arch = A.ARCHS["small"]
    params = M.init_params(arch, jax.random.PRNGKey(5))
    x = jax.random.uniform(jax.random.PRNGKey(6), (9, *arch["input"]), jnp.float32)
    y_pallas = M.forward(arch, params, x)
    y_ref = M.forward_ref(arch, params, x)
    np.testing.assert_allclose(y_pallas, y_ref, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("name", ["cfg_a", "cfg_b"])
def test_paper_archs_forward_ref_consistency(name):
    arch = A.ARCHS[name]
    params = M.init_params(arch, jax.random.PRNGKey(1))
    x = jax.random.uniform(jax.random.PRNGKey(2), (2, *arch["input"]), jnp.float32)
    np.testing.assert_allclose(
        M.forward(arch, params, x), M.forward_ref(arch, params, x), rtol=1e-4, atol=1e-5
    )
