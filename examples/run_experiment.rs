//! The declarative pipeline in one file: load the checked-in quickstart
//! spec, shrink it to demo scale, run datagen → train → eval → export
//! (all artifact-free), then serve the exported run directory through the
//! `api::Deployment` facade — the full paper loop, one typed API.
//!
//! ```sh
//! cargo run --release --example run_experiment
//! # the CLI equivalent of the full-size run:
//! cargo run --release -p semulator -- run --spec examples/specs/quickstart.json
//! ```

use semulator::api::{Deployment, MacRequest, VariantDef};
use semulator::pipeline::{Experiment, ExperimentSpec, RunOptions};
use semulator::xbar::CellInputs;

fn main() -> anyhow::Result<()> {
    // 1. A run spec: scenario + network + sampling + training recipe +
    //    probes, JSON-round-trippable (see examples/specs/quickstart.json
    //    for the schema). Shrunk here so the demo finishes in seconds.
    let mut spec =
        ExperimentSpec::from_str(&std::fs::read_to_string("examples/specs/quickstart.json")?)?;
    spec.name = "demo".into();
    spec.data.n_samples = 128;
    spec.train.epochs = 10;

    // 2. One call: golden datagen, guarded split, native SGD training,
    //    eval, and an export that is itself served by the probe stage.
    let summary = Experiment::new(spec)?.run(
        &RunOptions::new("runs/experiments/demo"),
        &mut |row| {
            if let Some(test) = row.test_loss {
                println!("epoch {:>3}  train {:.3e}  test {test:.3e}", row.epoch, row.train_loss);
            }
        },
    )?;
    println!(
        "trained: {} steps, test MAE {:.4} mV over {} held-out outputs",
        summary.report.steps,
        summary.report.test.mae * 1e3,
        summary.report.test.n
    );
    if let Some(p) = &summary.probe {
        println!("probe (served from the run dir): emulated MAE {:.4} mV (n = {})", p.emulator_mae * 1e3, p.n);
    }

    // 3. The run directory is a deployment artifact: load it and ask the
    //    served emulator one question.
    let dep = Deployment::builder().variant(VariantDef::from_run_dir(&summary.run_dir)?).build()?;
    let block = dep.block_config("demo")?.clone();
    let resp = dep.submit(&MacRequest::new("demo", CellInputs::zeros(&block)))?;
    println!(
        "served from {}: y = {:?} via {:?} ({:?})",
        summary.run_dir.display(),
        resp.outputs,
        resp.route,
        resp.backend
    );
    Ok(())
}
