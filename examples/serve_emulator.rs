//! Serving demo: one `api::Deployment` hosting *two named variants* of
//! the same trained network — the ideal device and a mild non-ideal
//! corner — behind the TCP line protocol, driven by concurrent clients
//! that pick their variant per request. Prints the per-variant metrics.
//!
//! ```sh
//! cargo run --release --example serve_emulator      # no artifacts needed
//! ```
//!
//! All the wiring this example used to do by hand (batcher + router +
//! metrics plumbing) now lives in `Deployment::builder()`.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use semulator::api::{Deployment, VariantDef};
use semulator::coordinator::{Policy, Server};
use semulator::datagen::SampleDist;
use semulator::model::ModelState;
use semulator::repro::block_for;
use semulator::util::{json_parse, Json, Rng};
use semulator::xbar::NonIdealSpec;

fn main() -> anyhow::Result<()> {
    // Use a trained checkpoint when available, else fresh weights (the
    // protocol demo does not depend on accuracy).
    let meta = semulator::infer::load_or_builtin_meta(std::path::Path::new("artifacts"), "small")?;
    let ckpt = std::path::Path::new("runs/ckpt/e2e_small.ckpt");
    let state = if ckpt.exists() {
        println!("using trained checkpoint {}", ckpt.display());
        ModelState::load(ckpt, &meta)?
    } else {
        println!("no checkpoint found — serving untrained weights (run e2e_train first for accuracy)");
        ModelState::init(&meta, 0)
    };

    // One process, two named variants: the same network shadow-verified
    // against the ideal golden block and against a mild device corner.
    let deployment = Arc::new(
        Deployment::builder()
            .variant(VariantDef::new("small").state(state.clone()))
            .variant(
                VariantDef::new("small_mild")
                    .arch("small")
                    .nonideal(NonIdealSpec::preset("mild").map_err(anyhow::Error::msg)?)
                    .state(state),
            )
            .policy(Policy::Shadow { verify_frac: 0.1 })
            .seed(7)
            .build()?,
    );
    let server = Server::spawn("127.0.0.1:0", deployment.clone())?;
    println!("server listening on {} (variants: {})", server.addr, deployment.variants().join(", "));

    // 4 concurrent clients x 16 requests each, alternating variants.
    let addr = server.addr;
    std::thread::scope(|scope| {
        for client in 0..4u64 {
            scope.spawn(move || {
                let mut rng = Rng::seed_from(100 + client);
                let stream = TcpStream::connect(addr).expect("connect");
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut stream = stream;
                let cfg = block_for("small").unwrap();
                for i in 0..16 {
                    let variant = if (client + i) % 2 == 0 { "small" } else { "small_mild" };
                    let x = SampleDist::UniformIid.sample(&cfg, &mut rng);
                    let req = Json::obj(vec![
                        ("variant", Json::Str(variant.into())),
                        ("v", Json::arr_f64(&x.v)),
                        ("g", Json::arr_f64(&x.g)),
                    ]);
                    stream.write_all(req.to_string().as_bytes()).unwrap();
                    stream.write_all(b"\n").unwrap();
                    let mut line = String::new();
                    reader.read_line(&mut line).unwrap();
                    let reply = json_parse(line.trim()).unwrap();
                    if client == 0 && i < 2 {
                        println!("sample reply ({variant}): {}", line.trim());
                    }
                    assert!(reply.get("y").is_some(), "bad reply: {line}");
                    assert_eq!(reply.get("variant").unwrap().as_str(), Some(variant));
                }
            });
        }
    });

    // Ask the server for its metrics over the wire: per-variant counters
    // under "variants", deployment-wide sums at the top level.
    let mut stream = TcpStream::connect(server.addr)?;
    stream.write_all(b"{\"cmd\":\"metrics\"}\n")?;
    let mut line = String::new();
    BufReader::new(stream.try_clone()?).read_line(&mut line)?;
    let snap = json_parse(line.trim()).map_err(anyhow::Error::msg)?;
    println!("total requests: {:?}", snap.get("requests").and_then(|v| v.as_f64()));
    for variant in deployment.variants() {
        let v = snap.get("variants").and_then(|m| m.get(variant));
        println!(
            "  {variant}: requests {:?}, verified {:?}",
            v.and_then(|m| m.get("requests")).and_then(|x| x.as_f64()),
            v.and_then(|m| m.get("verified")).and_then(|x| x.as_f64()),
        );
    }
    println!("local snapshot: {}", deployment.metrics_json().to_string_pretty());
    Ok(())
}
