//! Serving demo: stand up the TCP simulation server on an ephemeral port,
//! drive it with concurrent clients speaking the JSON line protocol, and
//! print the server-side metrics — the "SEMULATOR as a SPICE replacement
//! service" deployment story.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_emulator
//! ```
//!
//! Robustness-eval flow: the production CLI can run this same stack with
//! the golden shadow block perturbed by a device non-ideality scenario
//! (`semulator serve ... --nonideal mild`), and sweep a trained checkpoint
//! against the perturbed golden block offline with
//! `semulator eval --backend native --nonideal harsh --probe 256 ...`.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use semulator::coordinator::{BatcherConfig, EmulatorService, Metrics, Policy, Router, Server};
use semulator::datagen::SampleDist;
use semulator::model::ModelState;
use semulator::repro::block_for;
use semulator::runtime::ArtifactStore;
use semulator::util::{json_parse, Json, Rng};
use semulator::xbar::AnalogBlock;

fn main() -> anyhow::Result<()> {
    let variant = "small";
    let dir = std::path::PathBuf::from("artifacts");
    let store = ArtifactStore::open(&dir)?;
    let meta = store.meta.variant(variant)?.clone();

    // Use a trained checkpoint when available, else fresh weights (the
    // protocol demo does not depend on accuracy).
    let ckpt = std::path::Path::new("runs/ckpt/e2e_small.ckpt");
    let state = if ckpt.exists() {
        println!("using trained checkpoint {}", ckpt.display());
        ModelState::load(ckpt, &meta)?
    } else {
        println!("no checkpoint found — serving untrained weights (run e2e_train first for accuracy)");
        ModelState::init(&meta, 0)
    };

    let metrics = Arc::new(Metrics::default());
    let service =
        EmulatorService::spawn(dir, variant, state, BatcherConfig::default(), metrics.clone())?;
    let block_cfg = block_for(variant)?;
    let router = Arc::new(Router::new(
        AnalogBlock::new(block_cfg.clone()).map_err(anyhow::Error::msg)?,
        service.handle(),
        Policy::Shadow { verify_frac: 0.1 },
        metrics.clone(),
        7,
    ));
    let server = Server::spawn("127.0.0.1:0", router, metrics.clone())?;
    println!("server listening on {}", server.addr);

    // 4 concurrent clients x 16 requests each.
    let addr = server.addr;
    std::thread::scope(|scope| {
        for client in 0..4u64 {
            scope.spawn(move || {
                let mut rng = Rng::seed_from(100 + client);
                let stream = TcpStream::connect(addr).expect("connect");
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut stream = stream;
                let cfg = block_for("small").unwrap();
                for i in 0..16 {
                    let x = SampleDist::UniformIid.sample(&cfg, &mut rng);
                    let req =
                        Json::obj(vec![("v", Json::arr_f64(&x.v)), ("g", Json::arr_f64(&x.g))]);
                    stream.write_all(req.to_string().as_bytes()).unwrap();
                    stream.write_all(b"\n").unwrap();
                    let mut line = String::new();
                    reader.read_line(&mut line).unwrap();
                    let reply = json_parse(line.trim()).unwrap();
                    if client == 0 && i == 0 {
                        println!("sample reply: {}", line.trim());
                    }
                    assert!(reply.get("y").is_some(), "bad reply: {line}");
                }
            });
        }
    });

    // Ask the server for its metrics over the wire.
    let mut stream = TcpStream::connect(server.addr)?;
    stream.write_all(b"{\"cmd\":\"metrics\"}\n")?;
    let mut line = String::new();
    BufReader::new(stream.try_clone()?).read_line(&mut line)?;
    println!("server metrics: {}", line.trim());
    println!("local snapshot: {}", metrics.snapshot().to_string_pretty());
    Ok(())
}
