//! The scenario-sweep loop in one file: load the checked-in quickstart
//! campaign (non-ideality x dataset seed, 2x2), shrink it to demo scale,
//! run the whole grid across worker threads (artifact-free), print the
//! robustness matrix, then serve the leaderboard as one multi-variant
//! deployment via `DeploymentBuilder::from_campaign`.
//!
//! ```sh
//! cargo run --release --example run_campaign
//! # the CLI equivalent of the full-size sweep:
//! cargo run --release -p semulator -- sweep --spec examples/specs/sweep_quickstart.json --workers 2
//! ```

use semulator::api::{DeploymentBuilder, MacRequest};
use semulator::pipeline::{Campaign, CampaignOptions, CampaignSpec, RunStatus};
use semulator::xbar::CellInputs;

fn main() -> anyhow::Result<()> {
    // 1. A campaign spec: one base ExperimentSpec plus sweep axes whose
    //    cross-product is the grid (see examples/specs/sweep_quickstart.json
    //    for the schema). Shrunk here so the demo finishes in seconds.
    let mut spec = CampaignSpec::from_str(&std::fs::read_to_string(
        "examples/specs/sweep_quickstart.json",
    )?)?;
    spec.name = "demo_campaign".into();
    spec.base.data.n_samples = 48;
    spec.base.train.epochs = 2;

    // 2. One call runs the whole grid: each point is a full
    //    datagen -> train -> eval -> export experiment in its own run dir;
    //    failures become report rows, and summary.json/summary.csv land in
    //    the campaign directory. Re-running with .resume(true) would skip
    //    every up-to-date run.
    let campaign = Campaign::new(spec)?;
    let opts = CampaignOptions::new("runs/campaigns/demo").workers(2);
    let report = campaign.run(&opts)?;
    println!("robustness matrix ({} runs, {} failed):", report.rows.len(), report.n_failed);
    for row in &report.rows {
        match (&row.status, &row.eval) {
            (RunStatus::Failed(e), _) => println!("  {:<16} FAILED: {e}", row.name),
            (_, Some(e)) => println!(
                "  {:<16} mse {:.3e}  probe {:.4} mV",
                row.name,
                e.test_mse,
                e.probe_emulator_mae.unwrap_or(f64::NAN) * 1e3
            ),
            _ => {}
        }
    }
    println!("leaderboard: {}", report.leaderboard.join(" > "));

    // 3. The campaign directory is a deployment artifact: serve the top-2
    //    runs as named variants of one session and ask the best one a
    //    question.
    let dep = DeploymentBuilder::from_campaign(&report.campaign_dir, 2)?.build()?;
    let best = report.leaderboard[0].clone();
    let block = dep.block_config(&best)?.clone();
    let resp = dep.submit(&MacRequest::new(best, CellInputs::zeros(&block)))?;
    println!(
        "served [{}] from {}: best answered {:?} via {:?}",
        dep.variants().join(", "),
        report.campaign_dir.display(),
        resp.outputs,
        resp.route
    );
    Ok(())
}
