//! Crossbar-mapped network inference: program a weight matrix onto
//! emulated tiles as differential conductance pairs, run it through the
//! per-tile MAC executors, then score a small trained MLP's accuracy
//! under device non-idealities — all artifact-free.
//!
//! ```sh
//! cargo run --release --example nn_inference
//! ```

use semulator::nn::{nn_eval, AdcSpec, Executor, LayerOpts, NnSpec, XbarLinear};
use semulator::xbar::NonIdealSpec;

fn main() -> anyhow::Result<()> {
    // 1. One fully-connected layer by hand: y = Wx + b, each signed
    //    weight split across a G+/G- bitline pair, inputs bit-sliced
    //    onto the wordlines, partial sums accumulated across tiles.
    let w = vec![0.5, -0.25, 1.0, 0.0, -1.0, 0.125, 0.75, -0.5];
    let (n_out, n_in) = (2, 4);
    let opts = LayerOpts {
        tile_rows: 4,
        tile_outs: 2,
        w_max: 1.0,
        input_bits: 2,
        adc: AdcSpec { bits: 8, range: 8.0 },
        in_scale: 1.0,
        nonideal: NonIdealSpec::default(),
    };
    let layer = XbarLinear::program(&w, &[0.1, -0.1], n_out, n_in, &opts)
        .map_err(anyhow::Error::msg)?;
    let x = vec![1.0, 0.5, 0.25, 0.0];
    for (tag, exec) in [("ideal", Executor::Ideal), ("fast", Executor::Fast)] {
        let backend = exec.prepare(&layer.tiled).map_err(anyhow::Error::msg)?;
        let y = layer.forward(&backend, &x).map_err(anyhow::Error::msg)?;
        println!("{tag:>5} executor: y = [{:+.4}, {:+.4}]", y[0], y[1]);
    }

    // 2. The full pipeline: train a software MLP on the built-in
    //    tiny-image task, program it onto tiles, and measure how device
    //    scenarios eat into its accuracy. The `fast` executor solves
    //    every tile with the structured analog solver.
    let spec = NnSpec {
        executor: "fast".into(),
        hidden: 8,
        input_bits: 2,
        adc_bits: 6,
        adc_range: 6.0,
        n_train: 96,
        n_test: 32,
        epochs: 16,
        ..NnSpec::default()
    };
    for preset in ["ideal", "mild", "harsh"] {
        let ni = NonIdealSpec::preset(preset).map_err(anyhow::Error::msg)?;
        let r = nn_eval(&spec, &ni)?;
        println!(
            "{preset:>6} device: accuracy {:.3} (software baseline {:.3}), \
             {} tile MACs, {} ADC clips",
            r.accuracy, r.soft_accuracy, r.tile_macs, r.adc_clips
        );
    }
    println!("-> sweep it: cargo run --release -- nn-eval --spec examples/specs/nn_quickstart.json");
    Ok(())
}
