//! Quickstart: simulate an analog MAC block, generate a tiny SPICE dataset,
//! and serve the neural emulator through the `api::Deployment` facade —
//! no compiled artifacts needed for any step.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use semulator::api::{Deployment, MacRequest, VariantDef};
use semulator::coordinator::Policy;
use semulator::datagen::{generate, GenConfig, SampleDist};
use semulator::util::Rng;
use semulator::xbar::{AnalogBlock, BlockConfig, CellInputs, NonIdealSpec};

fn main() -> anyhow::Result<()> {
    // 1. An analog computing block: 2 tiles x 16 rows x 2 columns of 1T1R
    //    cells + one differential charge-sense MAC.
    let cfg = BlockConfig::small();
    let block = AnalogBlock::new(cfg.clone()).map_err(anyhow::Error::msg)?;
    println!("block: {:?} -> {} output(s), {} cells", cfg.input_shape(), cfg.n_mac(), cfg.n_cells());

    // 2. Simulate one read: activations on the gates, conductances as weights.
    let mut rng = Rng::seed_from(1);
    let mut x = CellInputs::zeros(&cfg);
    for k in 0..cfg.n_cells() {
        x.v[k] = rng.range(0.0, cfg.v_gate_max);
        x.g[k] = rng.range(cfg.cell.g_min, cfg.cell.g_max);
    }
    let fast = block.simulate(&x);
    let golden = block.simulate_golden(&x).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("fast structured solver: {:.6} V", fast[0]);
    println!("golden full-MNA SPICE : {:.6} V (|diff| {:.2e} V)", golden[0], (fast[0] - golden[0]).abs());

    // 2b. The same read on a non-ideal device: 5% programming spread, IR
    //     drop along the bitlines, rare stuck cells (preset "mild"). The
    //     CLI exposes this axis as `datagen --nonideal <preset>` (perturbed
    //     training data) and `eval --backend native --nonideal <preset>`
    //     (robustness sweep of the emulator vs the perturbed golden block).
    let pert_block = AnalogBlock::new(
        cfg.clone().with_nonideal(NonIdealSpec::preset("mild").map_err(anyhow::Error::msg)?),
    )
    .map_err(anyhow::Error::msg)?;
    let pert = pert_block.simulate(&x);
    println!("mild non-ideal device    : {:.6} V (shift {:+.2e} V)", pert[0], pert[0] - fast[0]);

    // 3. A small training dataset straight from the simulator.
    let ds = generate(&GenConfig { dist: SampleDist::UniformIid, ..GenConfig::new(cfg.clone(), 256, 7) });
    println!("dataset: {} samples, {} features -> {} outputs", ds.n, ds.d, ds.o);
    println!("target mean |V|: {:.4}", ds.target_mean_abs()[0]);

    // 4. The neural emulator behind the serving facade: one Deployment,
    //    one typed request, shadow-verified against the golden block.
    //    (Untrained weights — a shapes/wiring demo; train for accuracy.)
    let dep = Deployment::builder()
        .variant(VariantDef::new("small").init_seed(0))
        .policy(Policy::Shadow { verify_frac: 1.0 })
        .build()?;
    let resp = dep.submit(&MacRequest::new("small", x.clone()))?;
    println!(
        "emulator (untrained, via Deployment): {:.6} V, route {:?}, |emul - golden| = {:.4} V",
        resp.outputs[0],
        resp.route,
        resp.verify_dev.unwrap_or(f64::NAN)
    );
    println!("-> train it: cargo run --release -- train --variant small --data <dataset>");
    Ok(())
}
