//! Design-space exploration — the workload the paper's introduction
//! motivates: an analog-hardware designer sweeping *peripheral circuit*
//! choices without re-entering a commercial SPICE flow.
//!
//! We sweep the PS32 sense capacitance and amplifier transconductance and
//! measure, per design point, the MAC's output dynamic range, its
//! linearity against the ideal weighted sum, and the per-read simulation
//! cost — all on the SPICE-accurate structured solver. This is the
//! "SEMULATOR lets you choose peripherals freely" argument made concrete:
//! the same dataset/training pipeline works for every point in this sweep.
//!
//! ```sh
//! cargo run --release --example design_space
//! ```

use std::time::Instant;

use semulator::datagen::SampleDist;
use semulator::util::Rng;
use semulator::xbar::{AnalogBlock, BlockConfig, CellInputs};

/// Ideal (software) MAC the analog block approximates: sum of G*V over the
/// + column minus the - column, normalized to its own max.
fn ideal_mac(cfg: &BlockConfig, x: &CellInputs) -> f64 {
    let mut acc = 0.0;
    for t in 0..cfg.tiles {
        for r in 0..cfg.rows {
            for (j, sign) in [(0usize, 1.0), (1usize, -1.0)] {
                let k = CellInputs::idx(cfg, t, r, j);
                acc += sign * x.g[k] * x.v[k];
            }
        }
    }
    acc
}

/// Pearson correlation.
fn corr(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len() as f64;
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for (x, y) in a.iter().zip(b) {
        cov += (x - ma) * (y - mb);
        va += (x - ma) * (x - ma);
        vb += (y - mb) * (y - mb);
    }
    cov / (va.sqrt() * vb.sqrt()).max(1e-30)
}

fn main() -> anyhow::Result<()> {
    println!("PS32 peripheral design sweep on the small block (SPICE-accurate fast solver)");
    println!(
        "{:>10} {:>10} {:>12} {:>12} {:>12} {:>10}",
        "c_sense", "gm_amp", "out range", "linearity", "clip frac", "us/read"
    );

    let mut rng = Rng::seed_from(2024);
    let base = BlockConfig::small();
    let inputs: Vec<CellInputs> =
        (0..96).map(|_| SampleDist::UniformIid.sample(&base, &mut rng)).collect();
    let ideals: Vec<f64> = inputs.iter().map(|x| ideal_mac(&base, x)).collect();

    let mut best: Option<(f64, String)> = None;
    for c_sense in [0.25e-9, 0.5e-9, 1e-9, 2e-9] {
        for gm_amp in [0.25e-3, 1e-3, 4e-3] {
            let mut cfg = base.clone();
            cfg.periph.c_sense = c_sense;
            cfg.periph.gm_amp = gm_amp;
            let block = AnalogBlock::new(cfg.clone()).map_err(anyhow::Error::msg)?;
            let t0 = Instant::now();
            let outs: Vec<f64> = inputs.iter().map(|x| block.simulate(x)[0]).collect();
            let us = t0.elapsed().as_secs_f64() * 1e6 / inputs.len() as f64;
            let lo = outs.iter().cloned().fold(f64::INFINITY, f64::min);
            let hi = outs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let clip = outs.iter().filter(|o| o.abs() > 0.95 * cfg.periph.v_clamp).count() as f64
                / outs.len() as f64;
            let r = corr(&outs, &ideals);
            println!(
                "{:>9.2}nF {:>9.2}mS {:>11.1}mV {:>12.4} {:>12.3} {:>10.1}",
                c_sense * 1e9,
                gm_amp * 1e3,
                (hi - lo) * 1e3,
                r,
                clip,
                us
            );
            // Designer's figure of merit: linear AND uses the swing.
            let fom = r * ((hi - lo).min(1.0)) * (1.0 - clip);
            let tag = format!("c_sense={:.2}nF gm={:.2}mS", c_sense * 1e9, gm_amp * 1e3);
            if best.as_ref().map(|(b, _)| fom > *b).unwrap_or(true) {
                best = Some((fom, tag));
            }
        }
    }
    let (fom, tag) = best.unwrap();
    println!("\nbest design point by FoM (linearity x swing x headroom): {tag} (FoM {fom:.3})");
    println!("-> retrain the emulator for this peripheral: semulator datagen/train with the same pipeline");
    Ok(())
}
