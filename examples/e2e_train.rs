//! End-to-end system driver (EXPERIMENTS.md §E2E): proves all layers
//! compose on a real workload.
//!
//! 1. Generate a real SPICE dataset for the `small` block (thousands of
//!    transient simulations via the structured solver).
//! 2. Train SEMULATOR through the AOT PJRT train-step for a few hundred
//!    epochs with the paper's LR-halving schedule, logging the loss curve.
//! 3. Evaluate: MAE, MSE vs the Thm-4.1 bound, error Gaussianity.
//! 4. Stand up the serving stack (batcher + shadow router) and push a
//!    request burst, reporting latency/throughput vs the golden path.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_train [-- n_samples epochs]
//! ```

use std::time::Instant;

use semulator::api::{Deployment, MacRequest, VariantDef};
use semulator::coordinator::{train, LrSchedule, Policy, TrainConfig};
use semulator::datagen::{generate, GenConfig, SampleDist};
use semulator::repro::{predict_all, signed_errors};
use semulator::runtime::ArtifactStore;
use semulator::stats::{empirical_p_within, moments, mse_bound};
use semulator::util::Rng;
use semulator::xbar::{AnalogBlock, BlockConfig};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_samples: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(6000);
    let epochs: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(200);
    let variant = "small";
    let store = ArtifactStore::open(std::path::Path::new("artifacts"))?;
    let block_cfg = BlockConfig::small();

    // ---- 1. SPICE dataset ------------------------------------------------
    println!("[1/4] generating {n_samples} SPICE samples for {variant} ...");
    let t0 = Instant::now();
    let ds = generate(&GenConfig::new(block_cfg.clone(), n_samples, 0));
    println!(
        "      {:.1}s ({:.2} ms/sample); target mean |V| = {:.4}",
        t0.elapsed().as_secs_f64(),
        t0.elapsed().as_secs_f64() * 1e3 / n_samples as f64,
        ds.target_mean_abs()[0]
    );
    let (train_ds, test_ds) = ds.split(0.1, 0xA5)?;

    // ---- 2. train through PJRT -------------------------------------------
    println!("[2/4] training {epochs} epochs (PJRT train step, LR halved at 50/75/90%) ...");
    let mut cfg = TrainConfig::new(variant, epochs);
    cfg.lr = LrSchedule::paper_scaled(1e-3, epochs);
    cfg.eval_every = (epochs / 10).max(1);
    cfg.ckpt_out = Some("runs/ckpt/e2e_small.ckpt".into());
    let t0 = Instant::now();
    let (state, report) = train(&store, &cfg, &train_ds, &test_ds, |row| {
        if row.test_loss.is_some() || row.epoch % 25 == 0 {
            println!(
                "      epoch {:>4}  lr {:.2e}  train {:.3e}  test {}",
                row.epoch,
                row.lr,
                row.train_loss,
                row.test_loss.map(|v| format!("{v:.3e}")).unwrap_or_else(|| "-".into())
            );
        }
    })?;
    println!(
        "      {} steps in {:.1}s ({:.1} steps/s)",
        report.steps,
        t0.elapsed().as_secs_f64(),
        report.steps as f64 / t0.elapsed().as_secs_f64()
    );
    std::fs::create_dir_all("runs/results/e2e")?;
    std::fs::write("runs/results/e2e/loss_curve.csv", report.history_csv())?;
    println!("      loss curve -> runs/results/e2e/loss_curve.csv");

    // ---- 3. accuracy ------------------------------------------------------
    println!("[3/4] evaluation on {} held-out samples:", test_ds.n);
    println!(
        "      MAE {:.4} mV   MSE {:.3e}   P(|err|<0.5mV) {:.3}",
        report.test.mae * 1e3,
        report.test.mse,
        report.test.p_halfmv
    );
    let bound = mse_bound(3.0, 0.3);
    println!(
        "      Thm 4.1 bound (s=3,p=0.3) = {:.2e}: {}",
        bound,
        if report.test.mse < bound { "satisfied" } else { "not yet (more data/epochs)" }
    );
    let preds = predict_all(&store, variant, &state, &test_ds)?;
    let errs = signed_errors(&preds, &test_ds);
    let m = moments(&errs);
    println!(
        "      error dist: mean {:.2e}  std {:.2e}  skew {:.2}  ex-kurtosis {:.2} (Lemma 4.2: ~Gaussian)",
        m.mean,
        m.var.sqrt(),
        m.skew,
        m.kurtosis
    );
    println!("      P(|err|<1mV) = {:.3}", empirical_p_within(&errs, 1e-3));

    // ---- 4. serving -------------------------------------------------------
    println!("[4/4] serving: Deployment facade (shadow policy), 256-request burst ...");
    let deployment = Deployment::builder()
        .variant(VariantDef::new(variant).state(state))
        .policy(Policy::Shadow { verify_frac: 0.05 })
        .build()?;
    let n_req = 256;
    let mut rng = Rng::seed_from(99);
    let requests: Vec<_> = (0..n_req)
        .map(|_| MacRequest::new(variant, SampleDist::UniformIid.sample(&block_cfg, &mut rng)))
        .collect();
    let t0 = Instant::now();
    let mut max_dev: f64 = 0.0;
    std::thread::scope(|scope| {
        let threads: Vec<_> = requests
            .chunks(n_req / 8)
            .map(|chunk| {
                let deployment = &deployment;
                scope.spawn(move || {
                    let mut dev: f64 = 0.0;
                    for req in chunk {
                        let r = deployment.submit(req).expect("request failed");
                        if let Some(d) = r.verify_dev {
                            dev = dev.max(d);
                        }
                    }
                    dev
                })
            })
            .collect();
        for t in threads {
            max_dev = max_dev.max(t.join().unwrap());
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let metrics = deployment.variant_metrics(variant)?;
    println!(
        "      {} requests in {:.2}s -> {:.0} req/s (mean batch {:.1}, p50 {} us, p95 {} us)",
        n_req,
        wall,
        n_req as f64 / wall,
        deployment.batch_metrics().mean_batch_size(),
        metrics.latency.quantile_us(0.5),
        metrics.latency.quantile_us(0.95)
    );
    println!("      shadow verification max |emul - golden| = {:.3} mV", max_dev * 1e3);

    // Golden throughput for comparison.
    let block = AnalogBlock::new(block_cfg).map_err(anyhow::Error::msg)?;
    let t0 = Instant::now();
    for req in requests.iter().take(64) {
        std::hint::black_box(block.simulate(&req.inputs));
    }
    let golden_rate = 64.0 / t0.elapsed().as_secs_f64();
    println!(
        "      golden SPICE path: {:.0} req/s -> emulator speedup {:.1}x",
        golden_rate,
        (n_req as f64 / wall) / golden_rate
    );
    println!("e2e complete.");
    Ok(())
}
